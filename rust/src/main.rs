//! `pmvc` — CLI for the PMVC distribution study (Ayachi 2015 reproduction).
//!
//! ```text
//! pmvc table 4.2|4.3|4.4|4.5|4.6|4.7      regenerate a paper table
//! pmvc figures --series <s>               regenerate a figure series
//! pmvc sweep [--out results/sweep.csv]    full sweep -> CSV
//! pmvc run --matrix t2dal --combo NL-HL   one threaded PMVC run
//! pmvc serve --trace reqs.jsonl           solve-as-a-service session
//! pmvc recover --kill-node 1 --kill-apply 4   solve through a rank death
//! pmvc gen --matrix epb1 --out epb1.mtx   write a synthetic matrix
//! pmvc info                               artifacts + runtime status
//! ```

use pmvc::coordinator::cli::{parse_network, Args};
use pmvc::coordinator::experiment::{run_sweep, topology_for, ExperimentConfig};
use pmvc::coordinator::report;
use pmvc::partition::combined::{decompose, Combination, DecomposeConfig};
use pmvc::partition::{make_partitioner, PartitionError, PartitionerKind};
use pmvc::pmvc::{make_backend, BackendKind, ExecBackend, OverlapMode};
use pmvc::solver::SolverKind;
use pmvc::sparse::FormatKind;

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn config_from(args: &Args) -> pmvc::Result<ExperimentConfig> {
    let mut cfg = ExperimentConfig::default();
    if let Some(ms) = args.opt_list("matrices") {
        cfg.matrices = ms;
    }
    if let Some(ns) = args.opt_usizes("nodes")? {
        cfg.node_counts = ns;
    }
    if let Some(cs) = args.opt_list("combos") {
        cfg.combos = cs
            .iter()
            .map(|s| {
                Combination::parse(s).ok_or_else(|| anyhow::anyhow!("unknown combination '{s}'"))
            })
            .collect::<pmvc::Result<Vec<_>>>()?;
    }
    cfg.cores_per_node = args.opt_usize("cores", cfg.cores_per_node)?;
    cfg.seed = args.opt_u64("seed", cfg.seed)?;
    if let Some(net) = args.opt("network") {
        cfg.network = parse_network(net)?;
    }
    if let Some(b) = args.opt("backend") {
        cfg.backend = BackendKind::parse(b)
            .ok_or_else(|| anyhow::anyhow!("unknown backend '{b}' (threads|sim|mpi)"))?;
    }
    if args.has("overlap") {
        cfg.overlap = parse_overlap(args.opt_or("overlap", ""))?;
    }
    if let Some(s) = args.opt("solver") {
        cfg.solver = Some(SolverKind::parse(s).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown solver '{s}' (cg|pipelined-cg|sstep-cg|jacobi|sor|power|lanczos)"
            )
        })?);
    }
    cfg.s_step = args.opt_usize("s-step", cfg.s_step)?;
    if let Some(t) = args.opt("tol") {
        cfg.solver_tol = t.parse().map_err(|e| anyhow::anyhow!("--tol: {e}"))?;
    }
    cfg.solver_max_iters = args.opt_usize("iters", cfg.solver_max_iters)?;
    cfg.nrhs = args.opt_usize("nrhs", cfg.nrhs)?;
    if let Some(p) = args.opt("partitioner") {
        cfg.decompose.inter = make_partitioner(parse_partitioner(p)?)?;
    }
    if let Some(p) = args.opt("intra") {
        cfg.decompose.intra = make_partitioner(parse_partitioner(p)?)?;
    }
    if let Some(s) = args.opt("format") {
        cfg.decompose.format = parse_format(s)?;
    }
    if let Some(s) = args.opt("kernel") {
        cfg.decompose.kernel = parse_kernel(s)?;
    }
    Ok(cfg)
}

fn parse_format(s: &str) -> pmvc::Result<FormatKind> {
    FormatKind::parse(s)
        .ok_or_else(|| anyhow::anyhow!("unknown format '{s}' (csr|ell|dia|jad|bsr|csrdu|auto)"))
}

fn parse_kernel(s: &str) -> pmvc::Result<pmvc::sparse::KernelPolicy> {
    pmvc::sparse::KernelPolicy::parse(s)
        .ok_or_else(|| anyhow::anyhow!("unknown kernel policy '{s}' (scalar|tuned|auto)"))
}

fn parse_partitioner(s: &str) -> pmvc::Result<PartitionerKind> {
    Ok(PartitionerKind::parse(s)
        .ok_or_else(|| PartitionError::UnknownPartitioner { name: s.to_string() })?)
}

/// `--overlap` with no value selects the overlapped schedule; an
/// explicit value picks either mode.
fn parse_overlap(s: &str) -> pmvc::Result<OverlapMode> {
    if s.is_empty() {
        return Ok(OverlapMode::Overlapped);
    }
    OverlapMode::parse(s)
        .ok_or_else(|| anyhow::anyhow!("unknown overlap mode '{s}' (blocking|overlapped)"))
}

fn dispatch(args: &Args) -> pmvc::Result<()> {
    match args.command.as_str() {
        "table" => cmd_table(args),
        "figures" => cmd_figures(args),
        "sweep" => cmd_sweep(args),
        "run" => cmd_run(args),
        "serve" => cmd_serve(args),
        "recover" => cmd_recover(args),
        "gen" => cmd_gen(args),
        "info" => cmd_info(args),
        "" | "help" | "--help" => {
            println!("{}", HELP);
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}'; try `pmvc help`"),
    }
}

const HELP: &str = "pmvc — distribution of sparse matrix-vector products on a multicore cluster

USAGE: pmvc <command> [options]

COMMANDS:
  table <4.2|4.3|4.4|4.5|4.6|4.7>   regenerate a paper table
  figures --series <lb|scatter|compute|construct|gather|total>
  sweep [--out FILE.csv]            full simulated sweep
  run --matrix NAME --combo NL-HL --nodes F --cores C [--nrhs K]
      [--solver KIND [--s-step K]] [--kernel TIER] [--pin] [--xla]
  serve [--trace FILE.jsonl]        solve-as-a-service: one persistent
                                    coordinator multiplexes a request
                                    stream over a bounded admission
                                    queue, a fingerprint-keyed plan
                                    cache (LRU under --cache-bytes) and
                                    a pool of warm engines, then prints
                                    the service report (hit rate,
                                    latency percentiles, solves/sec)
  recover [--kill-node N --kill-apply K]
                                    one solve driven through the
                                    fault-tolerant coordinator: kill
                                    node N at the K-th distributed apply
                                    (1-based), replan over the
                                    survivors, warm-restart the solver
                                    from the checkpoint, and print the
                                    recovery report (add --csv FILE for
                                    a machine-readable row)
  gen --matrix NAME --out FILE.mtx  write a synthetic Table-4.2 matrix
  info                              artifacts + PJRT runtime status

COMMON OPTIONS:
  --matrices a,b,c   subset of Table 4.2 names, 'spd', or .mtx paths
  --nodes 2,4,8      node counts to sweep
  --combos NL-HL,..  combinations
  --cores N          cores per node (default 8)
  --network 10gbe    gbe|10gbe|ib|myrinet
  --backend KIND     threads|sim|mpi (sweep default: sim; run default: threads)
  --overlap [MODE]   blocking|overlapped (bare --overlap = overlapped):
                     double-buffer the X exchange — interior rows compute
                     while the halo is in flight. The CSV records the
                     schedule and the hidden time in the overlap and
                     t_overlap_saved columns.
  --partitioner K    inter-node strategy: contig|contig-balanced|cyclic|
                     nezgt|hypergraph (default nezgt). The sweep CSV
                     records it with the cut/comm_bytes quality columns.
                     `run` also accepts the 2-D kinds fine2d|checker
                     (nonzero-level partition + 2-D PMVC check).
  --intra K          intra-node strategy (default hypergraph)
  --format K         per-fragment kernel storage: csr|ell|dia|jad|bsr|
                     csrdu|auto (default csr — the construction format,
                     zero overhead). 'auto' scores each fragment's
                     structure (diagonal occupancy -> dia, uniform rows
                     -> ell, dense 4x4 blocks -> bsr, skewed rows ->
                     jad, compressible index stream -> csrdu). The CSV
                     records format and stored_bytes columns.
  --kernel TIER      kernel tier executing the fragments: scalar|tuned|
                     auto. 'tuned' runs the raw-speed loops — SIMD-lane
                     ELL/DIA/BSR, software-prefetched 4-row CSR/JAD,
                     L2-sized row tiles — and matches scalar to 1e-12
                     (CSR/DIA/JAD/CSR-DU bitwise). 'auto' currently
                     resolves to tuned. Default: scalar for sweep-style
                     commands (reference numbers), auto for `run`. The
                     CSV records the resolved tier in the kernel column.
  --pin              (`run` only) pin engine workers to NUMA-local CPUs
                     per the modeled topology and first-touch their
                     fragment storage; needs `--features numa` on
                     Linux, a silent no-op elsewhere. Never changes
                     result bits.
  --solver KIND      cg|pipelined-cg|sstep-cg|jacobi|sor|power|lanczos:
                     drive a full iterative solve through every sweep
                     cell (CSV gains solver, iterations and convergence
                     columns; phase times are per-iteration means).
                     '--matrices spd' generates an SPD system the linear
                     solvers converge on. The pipelined solvers fuse
                     their reductions with the next SpMV; the CSV
                     reports the reduction work and the part of it
                     hidden behind compute in the t_reduce and
                     t_pipeline_saved columns. `run` also accepts
                     --solver and prints the same two numbers.
  --s-step K         block size for sstep-cg (default 4): one fused
                     reduction per K iterations, 2K-1 SpMVs per block.
  --tol X            solver tolerance (default 1e-10)
  --iters N          solver iteration cap (default 1000)
  --nrhs K           right-hand sides per apply (default 1). Panels are
                     column-major; every backend carries all K columns
                     in one pass (matrix streamed once, one packed
                     K-slice halo message per neighbor). Sweep cells
                     batch the solver (cg -> block CG, jacobi ->
                     batched Jacobi) and the CSV gains nrhs plus
                     ;-joined col_iterations/col_converged columns.
                     `run` applies a K-wide panel and checks every
                     column against the serial product.
  --seed N           generator seed

SERVE OPTIONS (request fields fall back to the COMMON flags above;
`serve` reads --nodes/--cores as single values):
  --trace FILE       JSONL request trace, one object per line:
                     {\"matrix\": \"t2dal\", \"nrhs\": 8, \"solver\": \"cg\", ...}
                     (fields: matrix, combo, partitioner, intra, format,
                     solver, s_step, tol, iters, nrhs, nodes, cores,
                     seed, fault_node, fault_apply). A line carrying
                     fault_node + fault_apply has that node killed at
                     that 1-based apply mid-solve: the broken engine is
                     discarded and the request retried on a rebuilt one
                     (a typed 'recovered' outcome, never a drop).
                     Without --trace, a closed-loop workload over
                     --matrices (default t2dal,bcsstm09,spd) is
                     synthesised round-robin.
  --requests N       workload length without --trace (default 16)
  --max-requests N   truncate the request stream after N entries
  --queue-depth N    admission queue capacity (default 32)
  --reject-full      reject on a full queue (typed outcome) instead of
                     blocking the submitting client
  --engines N        engine-pool capacity (default 3)
  --workers N        worker threads (default 3)
  --clients N        closed-loop client threads (default 4)
  --cache-bytes N    plan-cache byte budget (default 256 MiB); LRU
                     eviction keeps at least the newest plan
  --no-cache         rebuild decomposition+plan+engine per request
                     (the baseline the cache is measured against)
  --report-json F    also dump the service report as JSON to F
  --min-hits N       fail unless the cache served >= N hits (CI gate)
  --min-evictions N  fail unless >= N evictions happened (CI gate)
  --min-recovered N  fail unless >= N requests were recovered after an
                     engine death (chaos CI gate)

RECOVER OPTIONS (plus --matrix/--combo/--partitioner/--intra/--format/
--kernel/--solver/--s-step/--tol/--iters/--nrhs/--nodes/--cores/--seed as above;
defaults: spd, cg, threads, 3x2, tol 1e-10; the pipelined solvers
checkpoint mid-pipeline state and warm-restart like cg):
  --kill-node N      node to kill (0-based; both flags together)
  --kill-apply K     1-based distributed apply at which the kill fires
  --csv FILE         append the recovery row as CSV (header written when
                     the file is new): matrix,solver,backend,f,c,
                     kill_node,kill_apply,restarts,repartitioned,
                     replan_s,iterations,converged,residual";

fn cmd_table(args: &Args) -> pmvc::Result<()> {
    let which = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("which table? (4.2 … 4.7)"))?;
    let cfg = config_from(args)?;
    match which.as_str() {
        "4.2" => print!("{}", report::matrix_table(cfg.seed)?),
        "4.3" | "4.4" | "4.5" | "4.6" => {
            let combo = match which.as_str() {
                "4.3" => Combination::NcHc,
                "4.4" => Combination::NcHl,
                "4.5" => Combination::NlHc,
                _ => Combination::NlHl,
            };
            let rows = run_sweep(&cfg)?;
            println!("Table {which} — combinaison {}", combo.name());
            print!("{}", report::combo_table(&rows, combo));
        }
        "4.7" => {
            let rows = run_sweep(&cfg)?;
            println!("Table 4.7 — récapitulation des résultats (meilleure combinaison par cas)");
            print!("{}", report::recap_table(&rows, &cfg.combos));
        }
        other => anyhow::bail!("unknown table '{other}'"),
    }
    Ok(())
}

fn cmd_figures(args: &Args) -> pmvc::Result<()> {
    let cfg = config_from(args)?;
    let series = args.opt_or("series", "total");
    let (name, metric): (&str, fn(&pmvc::pmvc::PhaseTimes) -> f64) = match series {
        "lb" => ("Équilibrage des charges (LB coeurs)", |t| t.lb_cores),
        "scatter" => ("Durée Scatter (s)", |t| t.t_scatter),
        "compute" => ("Temps de calcul de Y (s)", |t| t.t_compute),
        "construct" => ("Temps construction de Y (s)", |t| t.t_construct),
        "gather" => ("Gather + Construction (s)", |t| t.t_gather_construct()),
        "total" => ("Temps total du PMVC (s)", |t| t.t_total()),
        other => anyhow::bail!("unknown series '{other}'"),
    };
    let rows = run_sweep(&cfg)?;
    for m in &cfg.matrices {
        println!("{}", report::figure(&rows, m, name, metric, &cfg.combos));
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> pmvc::Result<()> {
    let cfg = config_from(args)?;
    let rows = run_sweep(&cfg)?;
    let csv = report::to_csv(&rows);
    match args.opt("out") {
        Some(path) => {
            std::fs::write(path, &csv)?;
            eprintln!("wrote {} rows to {path} ({})", rows.len(), report::backend_note(&rows));
        }
        None => print!("{csv}"),
    }
    Ok(())
}

fn cmd_run(args: &Args) -> pmvc::Result<()> {
    let matrix = args.opt_or("matrix", "t2dal");
    let combo = Combination::parse(args.opt_or("combo", "NL-HL"))
        .ok_or_else(|| anyhow::anyhow!("bad --combo"))?;
    let f = args.opt_usize("nodes", 2)?;
    let c = args.opt_usize("cores", 4)?;
    let seed = args.opt_u64("seed", 1)?;
    let kind = BackendKind::parse(args.opt_or("backend", "threads"))
        .ok_or_else(|| anyhow::anyhow!("unknown backend (threads|sim|mpi)"))?;
    let a = pmvc::coordinator::experiment::load_matrix(matrix, seed)?;
    let mut rng = pmvc::rng::SplitMix64::new(seed);
    let x: Vec<f64> = (0..a.n_cols).map(|_| rng.next_f64_range(-1.0, 1.0)).collect();

    // validate both strategy flags before branching, so a bad --intra is
    // diagnosed even on the 2-D path
    let inter_kind = args.opt("partitioner").map(parse_partitioner).transpose()?;
    let intra_kind = args.opt("intra").map(parse_partitioner).transpose()?;
    if let Some(pkind) = inter_kind.filter(|k| k.is_2d()) {
        // nonzero-level strategies bypass the 1-D two-level pipeline
        for (flag, given) in [
            ("--intra", intra_kind.is_some()),
            ("--combo", args.has("combo")),
            ("--backend", args.has("backend")),
            ("--network", args.has("network")),
            ("--overlap", args.has("overlap")),
            ("--format", args.has("format")),
            ("--kernel", args.has("kernel")),
            ("--pin", args.has("pin")),
            ("--nrhs", args.has("nrhs")),
            ("--xla", args.has("xla")),
        ] {
            if given {
                eprintln!("note: {flag} does not apply to the 2-D {pkind} partitioner; ignored");
            }
        }
        return run_2d(pkind, matrix, &a, &x, f, c);
    }
    let mut dcfg = DecomposeConfig::default();
    if let Some(k) = inter_kind {
        dcfg.inter = make_partitioner(k)?;
    }
    if let Some(k) = intra_kind {
        dcfg.intra = make_partitioner(k)?;
    }
    if let Some(s) = args.opt("format") {
        dcfg.format = parse_format(s)?;
    }

    let topo = topology_for(f, c);
    // the CLI defaults to `auto` (= the tuned tier) — raw speed by
    // default, `--kernel scalar` to reproduce the reference loops
    dcfg.kernel = args
        .opt("kernel")
        .map(parse_kernel)
        .transpose()?
        .unwrap_or(pmvc::sparse::KernelPolicy::Auto);
    dcfg.l2_bytes = topo.l2_bytes;
    let net = parse_network(args.opt_or("network", "10gbe"))?.model();
    let d = decompose(&a, combo, f, c, &dcfg)?;
    let mut backend = make_backend(kind, d.clone(), &topo, &net)?;
    if args.has("overlap") {
        backend.set_overlap_mode(parse_overlap(args.opt_or("overlap", ""))?)?;
    }
    if args.has("pin") {
        let pinned = backend.pin_workers(&topo);
        if pinned > 0 {
            println!("pinned {pinned} workers to NUMA-local CPUs (first-touch storage)");
        } else {
            println!("pinning unavailable (build with --features numa on Linux); running unpinned");
        }
    }
    let r = backend.apply(&x)?;
    let y_ref = a.matvec(&x);
    let max_err = r
        .y
        .iter()
        .zip(&y_ref)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);

    println!(
        "matrix={matrix} N={} NNZ={} combo={} f={f} cores={c} backend={}",
        a.n_rows,
        a.nnz(),
        combo,
        backend.name()
    );
    println!("LB_noeuds={:.3} LB_coeurs={:.3}", r.times.lb_nodes, r.times.lb_cores);
    println!(
        "partitioner={} cut={} comm_bytes={}",
        d.quality.label(),
        d.quality.cut,
        d.quality.comm_bytes
    );
    let census = d
        .format_census()
        .iter()
        .map(|(kind, count)| format!("{kind}:{count}"))
        .collect::<Vec<_>>()
        .join(",");
    println!(
        "format={} kernel={} stored_bytes={} fragments=[{census}]",
        dcfg.format,
        d.kernel_kind(),
        d.stored_bytes()
    );
    println!(
        "distribute(A)={:.6}s scatter={:.6}s compute={:.6}s construct={:.6}s gather={:.6}s total={:.6}s",
        backend.setup_time(),
        r.times.t_scatter,
        r.times.t_compute,
        r.times.t_construct,
        r.times.t_gather,
        r.times.t_total()
    );
    println!(
        "schedule={} t_overlap_saved={:.6}s",
        backend.overlap_mode(),
        r.times.t_overlap_saved
    );
    println!("max |y - y_ref| = {max_err:.3e}");
    anyhow::ensure!(max_err < 1e-8, "distributed result diverges from serial");

    let nrhs = args.opt_usize("nrhs", 1)?;
    anyhow::ensure!(nrhs >= 1, "--nrhs must be at least 1");
    if nrhs > 1 {
        // k-wide panel through the same backend: column j is the probe
        // vector rotated by j, so every column carries distinct data
        let n = x.len();
        let mut xp = Vec::with_capacity(n * nrhs);
        for j in 0..nrhs {
            let s = j % n;
            xp.extend_from_slice(&x[s..]);
            xp.extend_from_slice(&x[..s]);
        }
        let mut yp = vec![0.0; a.n_rows * nrhs];
        let tp = backend.apply_multi_into(&xp, &mut yp, nrhs)?;
        let mut panel_err = 0.0f64;
        for j in 0..nrhs {
            let yj_ref = a.matvec(&xp[j * n..(j + 1) * n]);
            for (yv, rv) in yp[j * a.n_rows..(j + 1) * a.n_rows].iter().zip(&yj_ref) {
                panel_err = panel_err.max((yv - rv).abs());
            }
        }
        println!(
            "panel nrhs={nrhs}: scatter={:.6}s compute={:.6}s gather={:.6}s total={:.6}s \
             t_overlap_saved={:.6}s",
            tp.t_scatter,
            tp.t_compute,
            tp.t_gather,
            tp.t_total(),
            tp.t_overlap_saved
        );
        println!("panel max |Y - Y_ref| = {panel_err:.3e}");
        anyhow::ensure!(panel_err < 1e-8, "panel result diverges from serial columns");
    }

    if let Some(s) = args.opt("solver") {
        use pmvc::solver::{make_solver_with, DistributedOp};
        let skind = SolverKind::parse(s).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown solver '{s}' (cg|pipelined-cg|sstep-cg|jacobi|sor|power|lanczos)"
            )
        })?;
        let s_step = args.opt_usize("s-step", 4)?;
        let tol: f64 =
            args.opt_or("tol", "1e-10").parse().map_err(|e| anyhow::anyhow!("--tol: {e}"))?;
        let iters = args.opt_usize("iters", 1000)?;
        // drive a full solve through the same backend the apply used;
        // b = A·x_true so the solve has a known answer
        let b = pmvc::service::rhs_panel(&a, 1, seed);
        let mut op = DistributedOp::with_backend(backend);
        let mut solver = make_solver_with(skind, &a, s_step)?;
        solver.options_mut().tol = tol;
        solver.options_mut().max_iters = iters;
        let r = solver.solve(&mut op, &b)?;
        let t = r.phases.unwrap_or_default();
        println!(
            "solver={} iterations={} converged={} residual={:.3e} t_reduce={:.6}s \
             t_pipeline_saved={:.6}s",
            r.solver, r.iterations, r.converged, r.residual_norm, t.t_reduce, t.t_pipeline_saved
        );
        anyhow::ensure!(r.converged, "solver {} did not converge", r.solver);
    }

    if args.has("xla") {
        let mut rt = pmvc::runtime::Runtime::new()?;
        println!("PJRT platform: {}", rt.platform());
        let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let mut y = vec![0f64; a.n_rows];
        let t0 = std::time::Instant::now();
        for frag in &d.fragments {
            if frag.csr.nnz() == 0 {
                continue;
            }
            let mut xl = vec![0f32; frag.csr.n_cols];
            for (lc, &g) in frag.global_cols.iter().enumerate() {
                xl[lc] = xf[g as usize];
            }
            let yl = rt.pfvc_csr(&frag.csr, &xl)?;
            for (lr, &g) in frag.global_rows.iter().enumerate() {
                y[g as usize] += yl[lr] as f64;
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        let max_err32 = y
            .iter()
            .zip(&y_ref)
            .map(|(a, b)| (a - b).abs() / (1.0 + b.abs()))
            .fold(0.0f64, f64::max);
        println!(
            "XLA path: {} executions, {} compiles, {dt:.4}s, max rel err = {max_err32:.3e}",
            rt.executions, rt.compiles
        );
        anyhow::ensure!(max_err32 < 1e-3, "XLA (f32) path diverges");
    }
    Ok(())
}

/// The 2-D (nonzero-level) run path: assign individual nonzeros with the
/// fine-grain hypergraph or the checkerboard grid, execute the
/// "version bloc 2D" PMVC, and report the exact 2-D communication
/// volume next to the load balance.
fn run_2d(
    pkind: PartitionerKind,
    matrix: &str,
    a: &pmvc::sparse::Csr,
    x: &[f64],
    f: usize,
    c: usize,
) -> pmvc::Result<()> {
    use pmvc::partition::hypergraph2d::{checkerboard, fine_grain_partition};
    use pmvc::partition::multilevel::Multilevel;
    let units = f * c;
    let owner = match pkind {
        PartitionerKind::Fine2d => fine_grain_partition(a, units, &Multilevel::default()),
        PartitionerKind::Checker => checkerboard(a, f, c),
        _ => anyhow::bail!("run_2d called with 1-D kind {pkind}"),
    };
    let y = owner.matvec_2d(a, x);
    let y_ref = a.matvec(x);
    let max_err = y
        .iter()
        .zip(&y_ref)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!(
        "matrix={matrix} N={} NNZ={} partitioner={} units={units} ({f}x{c})",
        a.n_rows,
        a.nnz(),
        pkind.name()
    );
    println!(
        "LB={:.3} comm_volume={} elements (2-D λ-1 over rows + columns)",
        owner.imbalance(a.nnz()),
        owner.comm_volume(a)
    );
    println!("max |y - y_ref| = {max_err:.3e}");
    anyhow::ensure!(max_err < 1e-8, "2-D distributed result diverges from serial");
    Ok(())
}

fn cmd_serve(args: &Args) -> pmvc::Result<()> {
    use pmvc::service::{parse_trace, run_service, workload, RequestDefaults, ServeConfig};

    let mut defaults = RequestDefaults::default();
    if let Some(c) = args.opt("combo") {
        defaults.combo =
            Combination::parse(c).ok_or_else(|| anyhow::anyhow!("unknown combination '{c}'"))?;
    }
    if let Some(p) = args.opt("partitioner") {
        defaults.partitioner = parse_partitioner(p)?;
    }
    if let Some(p) = args.opt("intra") {
        defaults.intra = parse_partitioner(p)?;
    }
    if let Some(s) = args.opt("format") {
        defaults.format = parse_format(s)?;
    }
    if let Some(s) = args.opt("solver") {
        defaults.solver = SolverKind::parse(s).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown solver '{s}' (cg|pipelined-cg|sstep-cg|jacobi|sor|power|lanczos)"
            )
        })?;
    }
    defaults.s_step = args.opt_usize("s-step", defaults.s_step)?;
    if let Some(t) = args.opt("tol") {
        defaults.tol = t.parse().map_err(|e| anyhow::anyhow!("--tol: {e}"))?;
    }
    defaults.max_iters = args.opt_usize("iters", defaults.max_iters)?;
    defaults.nrhs = args.opt_usize("nrhs", defaults.nrhs)?;
    defaults.nodes = args.opt_usize("nodes", defaults.nodes)?;
    defaults.cores = args.opt_usize("cores", defaults.cores)?;
    defaults.seed = args.opt_u64("seed", defaults.seed)?;

    let mut requests = match args.opt("trace") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("read trace {path}: {e}"))?;
            parse_trace(&text, &defaults)?
        }
        None => {
            let matrices = args.opt_list("matrices").unwrap_or_else(|| {
                vec!["t2dal".to_string(), "bcsstm09".to_string(), "spd".to_string()]
            });
            workload(&matrices, args.opt_usize("requests", 16)?, &defaults)
        }
    };
    let max_requests = args.opt_usize("max-requests", requests.len())?;
    requests.truncate(max_requests);
    anyhow::ensure!(!requests.is_empty(), "nothing to serve: the request stream is empty");

    let base = ServeConfig::default();
    let cfg = ServeConfig {
        queue_depth: args.opt_usize("queue-depth", base.queue_depth)?,
        engines: args.opt_usize("engines", base.engines)?,
        workers: args.opt_usize("workers", base.workers)?,
        clients: args.opt_usize("clients", base.clients)?,
        cache_bytes: args.opt_usize("cache-bytes", base.cache_bytes)?,
        cache_enabled: !args.has("no-cache"),
        reject_when_full: args.has("reject-full"),
        keep_solutions: false,
    };
    let n = requests.len();
    eprintln!(
        "serving {n} requests: {} clients -> queue({}) -> {} workers, {} engines, cache {}",
        cfg.clients,
        cfg.queue_depth,
        cfg.workers,
        cfg.engines,
        if cfg.cache_enabled { "on" } else { "off" },
    );
    let report = run_service(requests, &cfg)?;
    print!("{}", report.table());
    if let Some(path) = args.opt("report-json") {
        std::fs::write(path, report.to_json())?;
        eprintln!("wrote service report to {path}");
    }
    anyhow::ensure!(
        report.accounted() == n,
        "{} of {n} requests unaccounted for",
        n - report.accounted()
    );
    anyhow::ensure!(report.failed == 0, "{} requests failed", report.failed);
    let min_hits = args.opt_usize("min-hits", 0)?;
    anyhow::ensure!(
        report.cache_hits >= min_hits,
        "cache hits {} below the --min-hits {min_hits} gate",
        report.cache_hits
    );
    let min_evictions = args.opt_usize("min-evictions", 0)?;
    anyhow::ensure!(
        report.cache_evictions >= min_evictions,
        "cache evictions {} below the --min-evictions {min_evictions} gate",
        report.cache_evictions
    );
    let min_recovered = args.opt_usize("min-recovered", 0)?;
    anyhow::ensure!(
        report.recovered >= min_recovered,
        "recovered requests {} below the --min-recovered {min_recovered} gate",
        report.recovered
    );
    Ok(())
}

fn cmd_recover(args: &Args) -> pmvc::Result<()> {
    use pmvc::coordinator::{solve_with_recovery, RecoverySpec};
    use pmvc::pmvc::FaultPlan;
    use pmvc::service::rhs_panel;

    let matrix = args.opt_or("matrix", "spd");
    let combo = Combination::parse(args.opt_or("combo", "NL-HL"))
        .ok_or_else(|| anyhow::anyhow!("bad --combo"))?;
    let f = args.opt_usize("nodes", 3)?;
    let c = args.opt_usize("cores", 2)?;
    let seed = args.opt_u64("seed", 1)?;
    let backend = BackendKind::parse(args.opt_or("backend", "threads"))
        .ok_or_else(|| anyhow::anyhow!("unknown backend (threads|sim|mpi)"))?;
    let solver = SolverKind::parse(args.opt_or("solver", "cg")).ok_or_else(|| {
        anyhow::anyhow!("unknown solver (recovery supports cg|pipelined-cg|sstep-cg|jacobi)")
    })?;
    let nrhs = args.opt_usize("nrhs", 1)?;
    anyhow::ensure!(nrhs >= 1, "--nrhs must be at least 1");
    let tol: f64 = args
        .opt_or("tol", "1e-10")
        .parse()
        .map_err(|e| anyhow::anyhow!("--tol: {e}"))?;
    let max_iters = args.opt_usize("iters", 1000)?;

    let mut fault = FaultPlan::new();
    let (mut kill_node, mut kill_apply) = (0usize, 0usize);
    match (args.opt("kill-node"), args.opt("kill-apply")) {
        (None, None) => {}
        (Some(ns), Some(ks)) => {
            kill_node = ns.parse().map_err(|e| anyhow::anyhow!("--kill-node: {e}"))?;
            kill_apply = ks.parse().map_err(|e| anyhow::anyhow!("--kill-apply: {e}"))?;
            anyhow::ensure!(kill_node < f, "--kill-node {kill_node} out of range for {f} nodes");
            anyhow::ensure!(kill_apply >= 1, "--kill-apply is 1-based; 0 never fires");
            fault = fault.kill(kill_node, kill_apply);
        }
        _ => anyhow::bail!("--kill-node and --kill-apply must be given together"),
    }

    let mut dcfg = DecomposeConfig::default();
    if let Some(p) = args.opt("partitioner") {
        dcfg.inter = make_partitioner(parse_partitioner(p)?)?;
    }
    if let Some(p) = args.opt("intra") {
        dcfg.intra = make_partitioner(parse_partitioner(p)?)?;
    }
    if let Some(s) = args.opt("format") {
        dcfg.format = parse_format(s)?;
    }
    if let Some(s) = args.opt("kernel") {
        dcfg.kernel = parse_kernel(s)?;
    }

    let a = pmvc::coordinator::experiment::load_matrix(matrix, seed)?;
    let b = rhs_panel(&a, nrhs, seed);
    let spec = RecoverySpec {
        a: &a,
        combo,
        cfg: dcfg,
        backend,
        solver,
        s_step: args.opt_usize("s-step", 4)?,
        nrhs,
        f,
        c,
        tol,
        max_iters,
        fault: fault.clone(),
    };
    let out = solve_with_recovery(&spec, &b)?;

    println!(
        "matrix={matrix} N={} NNZ={} solver={solver} backend={backend} f={f} cores={c} nrhs={nrhs}",
        a.n_rows,
        a.nnz()
    );
    println!("fault schedule: {fault}");
    for (i, ev) in out.events.iter().enumerate() {
        println!(
            "restart {}: died at iteration {} ({} -> {} nodes), {} replan in {:.6}s",
            i + 1,
            ev.at_iteration,
            ev.f_before,
            ev.f_after,
            if ev.repartitioned { "reseeded repartition" } else { "same-recipe" },
            ev.replan_s
        );
    }
    println!(
        "result: iterations={} applies={} restarts={} warm_started={} converged={} \
         residual={:.3e} f_final={} wall={:.6}s",
        out.report.iterations,
        out.report.applies,
        out.report.restarts,
        out.report.warm_started,
        out.report.converged,
        out.report.residual_norm,
        out.f_final,
        out.report.wall_time
    );
    anyhow::ensure!(out.report.converged, "recovered solve did not converge");

    if let Some(path) = args.opt("csv") {
        let repartitioned = out.events.iter().any(|e| e.repartitioned);
        let replan_s: f64 = out.events.iter().map(|e| e.replan_s).sum();
        let mut csv = String::new();
        if !std::path::Path::new(path).exists() {
            csv.push_str(
                "matrix,solver,backend,f,c,kill_node,kill_apply,restarts,repartitioned,\
                 replan_s,iterations,converged,residual\n",
            );
        }
        csv.push_str(&format!(
            "{matrix},{solver},{backend},{f},{c},{kill_node},{kill_apply},{},{},{:.6},{},{},{:.3e}\n",
            out.report.restarts,
            repartitioned,
            replan_s,
            out.report.iterations,
            out.report.converged,
            out.report.residual_norm
        ));
        use std::io::Write;
        let mut file = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        file.write_all(csv.as_bytes())?;
        eprintln!("appended recovery row to {path}");
    }
    Ok(())
}

fn cmd_gen(args: &Args) -> pmvc::Result<()> {
    let matrix = args
        .opt("matrix")
        .ok_or_else(|| anyhow::anyhow!("--matrix required"))?;
    let out = args.opt("out").ok_or_else(|| anyhow::anyhow!("--out required"))?;
    let seed = args.opt_u64("seed", 1)?;
    let spec = pmvc::sparse::gen::MatrixSpec::paper(matrix)
        .ok_or_else(|| anyhow::anyhow!("unknown matrix '{matrix}'"))?;
    let m = pmvc::sparse::gen::generate(&spec, seed);
    pmvc::sparse::mm::write_matrix_market(out, &m)?;
    println!("wrote {} ({}x{}, {} nnz) to {out}", spec.name, m.n_rows, m.n_cols, m.nnz());
    Ok(())
}

fn cmd_info(_args: &Args) -> pmvc::Result<()> {
    let dir = pmvc::runtime::artifacts_dir();
    println!("artifacts dir: {dir:?}");
    match pmvc::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("{} artifact buckets:", m.entries.len());
            for e in &m.entries {
                println!(
                    "  {} ({}x{}, VMEM est. {} KiB)",
                    e.stem,
                    e.bucket.rows,
                    e.bucket.width,
                    e.bucket.vmem_bytes() / 1024
                );
            }
        }
        Err(e) => println!("no manifest: {e}"),
    }
    match pmvc::runtime::Runtime::new() {
        Ok(rt) => println!("PJRT platform: {}", rt.platform()),
        Err(e) => println!("runtime unavailable: {e}"),
    }
    Ok(())
}
