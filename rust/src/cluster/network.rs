//! α–β (Hockney) network model with collective cost formulas — the
//! substitute for the paper's measured Grid'5000 interconnect.
//!
//! A point-to-point message of `b` bytes costs `α + b·β` (latency +
//! inverse bandwidth). The PMVC uses two collectives (ch. 3 §4.2.3):
//! a personalized scatter (fan-out of A_k and X_k from the master) and a
//! gather-with-accumulation (fan-in of the partial Y_k). The master
//! serializes its sends/receives, which is exactly why the paper's
//! measured scatter/gather durations *grow* with the node count f even
//! though each message shrinks — the model reproduces that shape.

/// Point-to-point network parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NetworkModel {
    /// Per-message latency, seconds (α).
    pub latency: f64,
    /// Per-byte transfer time, seconds (β = 1/bandwidth).
    pub inv_bandwidth: f64,
    /// Fixed software overhead per posted message at the master
    /// (MPI envelope handling; makes many-small-messages expensive).
    pub per_message_overhead: f64,
}

/// Common interconnect presets (ch. 2 §4.2 discusses all three).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetworkPreset {
    /// Gigabit Ethernet: ~50 µs latency, 1 Gb/s.
    GigabitEthernet,
    /// 10 GbE — the paper's 'paravance' interconnect.
    TenGigabitEthernet,
    /// InfiniBand QDR: ~1.5 µs latency, 32 Gb/s.
    Infiniband,
    /// Myrinet: ~3 µs, 10 Gb/s.
    Myrinet,
}

impl NetworkPreset {
    /// The calibrated α–β model of this interconnect.
    pub fn model(&self) -> NetworkModel {
        match self {
            NetworkPreset::GigabitEthernet => NetworkModel {
                latency: 50e-6,
                inv_bandwidth: 8.0 / 1.0e9,
                per_message_overhead: 5e-6,
            },
            NetworkPreset::TenGigabitEthernet => NetworkModel {
                latency: 25e-6,
                inv_bandwidth: 8.0 / 10.0e9,
                per_message_overhead: 3e-6,
            },
            NetworkPreset::Infiniband => NetworkModel {
                latency: 1.5e-6,
                inv_bandwidth: 8.0 / 32.0e9,
                per_message_overhead: 0.5e-6,
            },
            NetworkPreset::Myrinet => NetworkModel {
                latency: 3e-6,
                inv_bandwidth: 8.0 / 10.0e9,
                per_message_overhead: 1e-6,
            },
        }
    }
}

impl NetworkModel {
    /// Cost of one point-to-point message of `bytes`.
    pub fn p2p(&self, bytes: usize) -> f64 {
        self.latency + bytes as f64 * self.inv_bandwidth
    }

    /// Personalized scatter from the master: the master sends a distinct
    /// message to each of `msg_bytes.len()` workers, serialized at its
    /// NIC (linear model — matches MPI_Scatterv on commodity Ethernet).
    pub fn scatter(&self, msg_bytes: &[usize]) -> f64 {
        let send_time: f64 = msg_bytes
            .iter()
            .map(|&b| self.per_message_overhead + b as f64 * self.inv_bandwidth)
            .sum();
        // one latency term overlaps across messages except the first
        self.latency + send_time
    }

    /// Gather at the master: workers send their partial results; the
    /// master's NIC serializes receptions the same way.
    pub fn gather(&self, msg_bytes: &[usize]) -> f64 {
        self.scatter(msg_bytes)
    }

    /// Effective bandwidth (bytes/s) for sanity checks.
    pub fn bandwidth(&self) -> f64 {
        1.0 / self.inv_bandwidth
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_ordered_by_latency() {
        let gbe = NetworkPreset::GigabitEthernet.model();
        let tge = NetworkPreset::TenGigabitEthernet.model();
        let ib = NetworkPreset::Infiniband.model();
        assert!(gbe.latency > tge.latency && tge.latency > ib.latency);
        assert!(ib.bandwidth() > tge.bandwidth());
    }

    #[test]
    fn p2p_affine_in_size() {
        let m = NetworkPreset::TenGigabitEthernet.model();
        let t0 = m.p2p(0);
        let t1 = m.p2p(1_000_000);
        assert!((t0 - m.latency).abs() < 1e-12);
        assert!((t1 - t0 - 1_000_000.0 * m.inv_bandwidth).abs() < 1e-12);
    }

    #[test]
    fn scatter_grows_with_node_count_at_fixed_total() {
        // the paper's fig. 4.16-4.23 shape: same total volume split over
        // more nodes costs MORE because of per-message overheads
        let m = NetworkPreset::TenGigabitEthernet.model();
        let total = 1_000_000usize;
        let t2 = m.scatter(&[total / 2; 2]);
        let t64 = m.scatter(&[total / 64; 64]);
        assert!(t64 > t2);
    }

    #[test]
    fn scatter_monotone_in_volume() {
        let m = NetworkPreset::GigabitEthernet.model();
        assert!(m.scatter(&[100, 100]) < m.scatter(&[1000, 1000]));
    }

    #[test]
    fn gather_equals_scatter_symmetry() {
        let m = NetworkPreset::Myrinet.model();
        let sizes = vec![123, 456, 789];
        assert_eq!(m.gather(&sizes), m.scatter(&sizes));
    }
}
