//! Cluster and node topology (ch. 2 §4, ch. 4 §3).
//!
//! A cluster is `f` identical nodes; each node holds one or more NUMA
//! banks with `cores_per_bank` cores each (fig. 4.6 shows 4 banks × 4
//! cores). The paper's test platform is 'paravance' (Rennes): 2 CPUs ×
//! 8 cores per node, 10 GbE interconnect; experiments use 8 cores/node.

/// One NUMA bank: a memory controller plus the cores attached to it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NumaNode {
    /// Cores attached to this bank.
    pub cores: usize,
    /// Local memory bandwidth, bytes/s.
    pub local_bw: f64,
    /// NUMA factor: remote-access time / local-access time (the paper
    /// cites 1.1–3.0 for current machines).
    pub numa_factor: f64,
}

/// The full machine description the simulator runs against.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterTopology {
    /// Number of compute nodes (f in the paper).
    pub nodes: usize,
    /// NUMA banks per node.
    pub banks_per_node: usize,
    /// Cores per bank.
    pub cores_per_bank: usize,
    /// Per-core effective stream bandwidth for SpMV (bytes/s). SpMV is
    /// memory-bound; compute time ≈ bytes_touched / bandwidth.
    pub core_bw: f64,
    /// Per-core flop rate ceiling (flops/s) — the roofline's other wing.
    pub core_flops: f64,
    /// NUMA factor between banks inside a node.
    pub numa_factor: f64,
    /// Per-core L2 capacity (bytes) — what the tuned kernel tier sizes
    /// its CSR row-block tiles from
    /// ([`crate::sparse::kernels::tile_rows_for`]).
    pub l2_bytes: usize,
}

impl ClusterTopology {
    /// The paper's 'paravance' setting: 8 cores per node used
    /// (2 banks × 4), Xeon E5-2630v3-class cores.
    pub fn paravance(nodes: usize) -> ClusterTopology {
        ClusterTopology {
            nodes,
            banks_per_node: 2,
            cores_per_bank: 4,
            // ~6 GB/s effective per-core stream share on a loaded 2014
            // Xeon socket; ~2.4 GHz × 4-wide FMA ceiling.
            core_bw: 6.0e9,
            core_flops: 19.2e9,
            numa_factor: 1.4,
            l2_bytes: crate::sparse::kernels::DEFAULT_L2_BYTES,
        }
    }

    /// Cores per node (the paper's fc = 8).
    pub fn cores_per_node(&self) -> usize {
        self.banks_per_node * self.cores_per_bank
    }

    /// Total cores in the cluster.
    pub fn total_cores(&self) -> usize {
        self.nodes * self.cores_per_node()
    }

    /// Which bank a core index (within a node) belongs to.
    pub fn bank_of_core(&self, core: usize) -> usize {
        core / self.cores_per_bank
    }

    /// Host CPU a modeled `(node, core)` worker should pin to, given the
    /// machine actually has `host_cpus` CPUs. Workers lay out
    /// bank-contiguously — node-major, then core order within the node,
    /// so the cores of one modeled bank land on adjacent host CPUs (the
    /// layout Linux enumerates NUMA banks in). Returns `None` when the
    /// host has fewer CPUs than the flattened index (oversubscribed —
    /// pinning would serialize workers, better to let the OS schedule).
    pub fn host_cpu_for(&self, node: usize, core: usize, host_cpus: usize) -> Option<usize> {
        let flat = node * self.cores_per_node() + core;
        (flat < host_cpus).then_some(flat)
    }

    /// Estimated time for one core to stream an SpMV fragment:
    /// CSR bytes = nnz·(8 val + 4 col) + rows·8 ptr-ish + x/y traffic,
    /// clamped below by the flop roofline (2 flops per nonzero).
    pub fn core_spmv_time(&self, nnz: usize, rows: usize, x_elems: usize) -> f64 {
        let bytes = nnz as f64 * 12.0 + rows as f64 * 12.0 + x_elems as f64 * 8.0;
        self.core_stream_time(bytes, nnz)
    }

    /// Memory-roofline time to stream `bytes` for a kernel doing 2
    /// flops per nonzero, clamped below by the flop ceiling — the
    /// general form [`ClusterTopology::core_spmv_time`] is a CSR
    /// instance of. The format-generic simulator prices each storage
    /// format's own bytes-touched model through this.
    pub fn core_stream_time(&self, bytes: f64, nnz: usize) -> f64 {
        let t_mem = bytes / self.core_bw;
        let t_flop = (2.0 * nnz as f64) / self.core_flops;
        t_mem.max(t_flop)
    }

    /// Intra-node reduction time for accumulating `vec_len`-element
    /// partial vectors from `parts` cores through the NUMA hierarchy.
    pub fn node_reduce_time(&self, vec_len: usize, parts: usize) -> f64 {
        if parts <= 1 || vec_len == 0 {
            return 0.0;
        }
        // tree reduction: log2(parts) rounds of vec_len adds, remote
        // rounds pay the NUMA factor
        let rounds = (parts as f64).log2().ceil();
        let bytes_per_round = vec_len as f64 * 8.0 * 2.0; // read+write
        rounds * bytes_per_round * self.numa_factor / self.core_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paravance_matches_paper() {
        let t = ClusterTopology::paravance(64);
        assert_eq!(t.cores_per_node(), 8);
        assert_eq!(t.total_cores(), 512);
        assert_eq!(t.bank_of_core(0), 0);
        assert_eq!(t.bank_of_core(5), 1);
        assert!(t.l2_bytes >= 64 * 1024);
    }

    #[test]
    fn host_cpu_mapping_is_bank_contiguous_and_bounded() {
        let t = ClusterTopology::paravance(2);
        // node-major, core order within node: (0,0)→0 … (0,7)→7, (1,0)→8
        assert_eq!(t.host_cpu_for(0, 0, 16), Some(0));
        assert_eq!(t.host_cpu_for(0, 7, 16), Some(7));
        assert_eq!(t.host_cpu_for(1, 0, 16), Some(8));
        assert_eq!(t.host_cpu_for(1, 7, 16), Some(15));
        // one modeled bank (4 cores) occupies adjacent host CPUs
        let bank: Vec<_> = (0..4).map(|c| t.host_cpu_for(0, c, 16).unwrap()).collect();
        assert_eq!(bank, vec![0, 1, 2, 3]);
        // oversubscribed host: no pin rather than a serializing pile-up
        assert_eq!(t.host_cpu_for(1, 7, 8), None);
        assert_eq!(t.host_cpu_for(0, 3, 4), Some(3));
        assert_eq!(t.host_cpu_for(0, 4, 4), None);
    }

    #[test]
    fn spmv_time_monotone_in_nnz() {
        let t = ClusterTopology::paravance(2);
        let t1 = t.core_spmv_time(1_000, 100, 500);
        let t2 = t.core_spmv_time(10_000, 100, 500);
        assert!(t2 > t1);
        assert!(t1 > 0.0);
    }

    #[test]
    fn reduce_time_zero_for_single_part() {
        let t = ClusterTopology::paravance(2);
        assert_eq!(t.node_reduce_time(1000, 1), 0.0);
        assert!(t.node_reduce_time(1000, 8) > t.node_reduce_time(1000, 2));
    }

    #[test]
    fn memory_bound_regime() {
        // SpMV at 0.17 flop/byte must be memory-bound on paravance
        let t = ClusterTopology::paravance(1);
        let nnz = 100_000;
        let bytes = nnz as f64 * 12.0;
        assert!(t.core_spmv_time(nnz, 1000, 1000) >= bytes / t.core_bw * 0.99);
    }
}
