//! Machine model of the test platform (ch. 2 and ch. 4 §3): a cluster of
//! multicore NUMA nodes connected by a commodity network — Grid'5000's
//! 'paravance' cluster in the paper, a calibrated analytic model here.

pub mod network;
pub mod topology;

pub use network::{NetworkModel, NetworkPreset};
pub use topology::{ClusterTopology, NumaNode};
