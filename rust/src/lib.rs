//! # pmvc — Distribution of Sparse Computations on a Multicore Cluster
//!
//! Reproduction of *"Étude de la Distribution de Calculs Creux sur une
//! Grappe Multi-cœurs"* (Mouadh Ayachi, 2015): distributing the sparse
//! matrix–vector product (PMVC, *Produit Matrice-Vecteur Creux*) over a
//! cluster of multicore NUMA nodes with a **two-level decomposition**:
//!
//! * **inter-node**: the NEZGT heuristic (row or column variant), which
//!   balances the nonzero count across node fragments, and
//! * **intra-node**: 1-D hypergraph partitioning (row or column nets),
//!   which minimizes the communication volume between cores,
//!
//! giving the four combinations `NC-HC`, `NC-HL`, `NL-HC`, `NL-HL`
//! studied in the paper's chapter 4.
//!
//! The crate is the L3 coordinator of a three-layer stack: the per-core
//! compute hot-spot (the *Produit Fragment-Vecteur Creux*, PFVC) is
//! authored as a JAX/Pallas kernel, AOT-lowered to HLO text at build time
//! (`make artifacts`) and executed from Rust through the PJRT C API
//! ([`runtime`]). A pure-Rust kernel ([`pmvc::spmv`]) provides the
//! reference path and the simulator cost model.
//!
//! ## Layout
//!
//! (See `ARCHITECTURE.md` at the repository root for the full
//! layer-by-layer guide with the data-flow diagram.)
//!
//! * [`sparse`] — COO/CSR/CSC/ELL formats plus the ch. 1 §2.3
//!   compression catalogue (DIA/JAD/BSR/CSR-DU) and the per-fragment
//!   kernel-storage registry ([`sparse::FormatKind`] /
//!   [`sparse::FragmentStorage`], `--format`, auto-selection via
//!   [`sparse::stats`]); the tuned raw-speed kernel tier
//!   ([`sparse::kernels`], `--kernel`: SIMD lanes, prefetch, L2 row
//!   tiles); MatrixMarket I/O; generators for the paper's 8-matrix
//!   SuiteSparse test suite.
//! * [`partition`] — every fragmentation strategy (NEZGT, multilevel
//!   hypergraph, PETSc-style baselines, 2-D fine-grain/checkerboard)
//!   behind the [`partition::Partitioner`] trait and
//!   [`partition::PartitionerKind`] registry; the combined two-level
//!   decomposition carries a [`partition::QualityReport`] (cut, comm
//!   bytes, load balance) so strategies compare on one scale.
//! * [`cluster`] — machine model: topology, NUMA banks, α–β network.
//! * [`pmvc`] — the distributed PMVC pipeline, split plan/engine:
//!   [`pmvc::plan`] precomputes the immutable communication plan
//!   (footprints, row maps, byte volumes, and the interior/boundary
//!   row split of the overlapped schedule) once per decomposition;
//!   [`pmvc::engine`] drives a persistent worker pool against it;
//!   [`pmvc::backend`] unifies the threaded, simulated and MPI-style
//!   runtimes behind one `ExecBackend` trait, each honoring the
//!   [`pmvc::OverlapMode`] knob (hide the halo exchange behind
//!   interior-row computation, or run the paper's blocking pipeline);
//!   [`pmvc::affinity`] pins workers to host CPUs (`numa` feature) so
//!   first-touch lands fragment storage on the owning bank.
//! * [`runtime`] — PJRT client, artifact loading, executable cache.
//! * [`solver`] — CG, Jacobi, Gauss-Seidel/SOR, Lanczos and power
//!   iteration unified behind the [`solver::IterativeSolver`] /
//!   [`solver::SolveReport`] API over the fallible, allocation-free
//!   [`solver::MatVecOp::apply_into`] contract (plan once, apply every
//!   iteration into reusable scratch).
//! * [`coordinator`] — experiment driver (backend-, solver- and
//!   partitioner-selectable sweeps), reporting, CLI.
//! * [`service`] — solve-as-a-service: a persistent coordinator with a
//!   bounded admission queue, a fingerprint-keyed plan cache
//!   (decomposition + frozen `CommPlan`, LRU under a byte budget) and a
//!   multiplexed pool of warm engines ([`service::run_service`],
//!   `coordinator serve`).

// Every public item carries documentation; the CI doc gate
// (`RUSTDOCFLAGS="-D warnings" cargo doc --no-deps`) promotes any
// regression to an error.
#![warn(missing_docs)]

pub mod cluster;
pub mod coordinator;
pub mod partition;
pub mod pmvc;
pub mod rng;
pub mod runtime;
pub mod service;
pub mod solver;
pub mod sparse;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
