//! Request schema, JSONL trace ingestion, and the built-in workload
//! driver.
//!
//! A served solve is described by a [`SolveRequest`]: the matrix source
//! (a Table 4.2 name, `spd`, or a MatrixMarket `.mtx` path), the
//! decomposition recipe (combination, inter/intra partitioners, storage
//! format, f × c shape) and the solve itself (solver, tolerance,
//! iteration cap, `nrhs`-wide RHS panel). Requests arrive two ways:
//!
//! - **trace replay** — [`parse_trace`] reads one flat JSON object per
//!   line (`#` comments and blank lines skipped); absent fields fall
//!   back to [`RequestDefaults`]. The parser is a deliberately tiny
//!   hand-rolled reader for flat objects of strings / numbers / bools —
//!   the crate takes no serde dependency for one trace format;
//! - **the closed-loop driver** — [`workload`] synthesises a
//!   deterministic round-robin stream over a matrix list, the shape used
//!   by the benches and CI smokes.

use crate::partition::combined::Combination;
use crate::partition::PartitionerKind;
use crate::solver::SolverKind;
use crate::sparse::gen::MatrixSpec;
use crate::sparse::FormatKind;

/// One solve request, as admitted to the service.
#[derive(Clone, Debug)]
pub struct SolveRequest {
    /// Request id (position in the trace / workload, echoed in the
    /// outcome).
    pub id: usize,
    /// Matrix source: Table 4.2 name, `spd`, or a `.mtx` path.
    pub matrix: String,
    /// Inter/intra axis combination.
    pub combo: Combination,
    /// Inter-node partitioner.
    pub partitioner: PartitionerKind,
    /// Intra-node partitioner.
    pub intra: PartitionerKind,
    /// Per-fragment storage format.
    pub format: FormatKind,
    /// Iterative method.
    pub solver: SolverKind,
    /// Block size for the s-step solver (ignored by the others).
    pub s_step: usize,
    /// Convergence tolerance.
    pub tol: f64,
    /// Iteration cap.
    pub max_iters: usize,
    /// Right-hand-side panel width (1 = classic single solve).
    pub nrhs: usize,
    /// Nodes.
    pub nodes: usize,
    /// Cores per node.
    pub cores: usize,
    /// Generator seed (synthetic sources) and RHS recipe seed.
    pub seed: u64,
    /// Chaos injection: node to kill mid-solve (with [`Self::fault_apply`]).
    pub fault_node: Option<usize>,
    /// Chaos injection: 1-based apply at which the kill fires.
    pub fault_apply: Option<usize>,
}

/// Fallbacks for fields a trace line (or the workload driver) leaves
/// unset.
#[derive(Clone, Debug)]
pub struct RequestDefaults {
    /// Inter/intra axis combination.
    pub combo: Combination,
    /// Inter-node partitioner.
    pub partitioner: PartitionerKind,
    /// Intra-node partitioner.
    pub intra: PartitionerKind,
    /// Per-fragment storage format.
    pub format: FormatKind,
    /// Iterative method.
    pub solver: SolverKind,
    /// s-step block size.
    pub s_step: usize,
    /// Convergence tolerance.
    pub tol: f64,
    /// Iteration cap.
    pub max_iters: usize,
    /// Right-hand-side panel width.
    pub nrhs: usize,
    /// Nodes.
    pub nodes: usize,
    /// Cores per node.
    pub cores: usize,
    /// Generator / RHS seed.
    pub seed: u64,
}

impl Default for RequestDefaults {
    fn default() -> Self {
        RequestDefaults {
            combo: Combination::NlHl,
            partitioner: PartitionerKind::Nezgt,
            intra: PartitionerKind::Hypergraph,
            format: FormatKind::Csr,
            solver: SolverKind::Cg,
            s_step: 4,
            tol: 1e-8,
            max_iters: 200,
            nrhs: 1,
            nodes: 2,
            cores: 2,
            seed: 1,
        }
    }
}

impl SolveRequest {
    /// Request `id` for `matrix` with every other field from `defaults`.
    pub fn new(id: usize, matrix: String, defaults: &RequestDefaults) -> Self {
        SolveRequest {
            id,
            matrix,
            combo: defaults.combo,
            partitioner: defaults.partitioner,
            intra: defaults.intra,
            format: defaults.format,
            solver: defaults.solver,
            s_step: defaults.s_step,
            tol: defaults.tol,
            max_iters: defaults.max_iters,
            nrhs: defaults.nrhs,
            nodes: defaults.nodes,
            cores: defaults.cores,
            seed: defaults.seed,
            fault_node: None,
            fault_apply: None,
        }
    }

    /// Admission validation: reject combinations the engine pipeline
    /// cannot serve *before* they occupy a queue slot. The returned
    /// string becomes the typed `Invalid` rejection reason.
    pub fn validate(&self) -> Result<(), String> {
        if self.matrix.is_empty() {
            return Err("empty matrix source".into());
        }
        if !self.matrix.ends_with(".mtx")
            && self.matrix != "spd"
            && MatrixSpec::paper(&self.matrix).is_none()
        {
            return Err(format!(
                "unknown matrix '{}' (not in Table 4.2, not 'spd', not a .mtx path)",
                self.matrix
            ));
        }
        if self.partitioner.is_2d() || self.intra.is_2d() {
            return Err(format!(
                "2-D partitioner '{}' cannot drive the plan/engine pipeline",
                if self.partitioner.is_2d() { self.partitioner } else { self.intra }
            ));
        }
        if self.nodes == 0 || self.cores == 0 {
            return Err(format!("degenerate cluster shape {}x{}", self.nodes, self.cores));
        }
        if self.nrhs == 0 {
            return Err("nrhs 0: an empty panel solves nothing".into());
        }
        if self.nrhs > 1 && !matches!(self.solver, SolverKind::Cg | SolverKind::Jacobi) {
            return Err(format!(
                "nrhs {} needs a batched solver (cg or jacobi), got '{}'",
                self.nrhs, self.solver
            ));
        }
        if self.max_iters == 0 {
            return Err("max_iters 0".into());
        }
        if self.s_step == 0 && self.solver == SolverKind::SStepCg {
            return Err("s_step 0: the s-step solver needs a block of at least 1".into());
        }
        if self.tol <= 0.0 || self.tol.is_nan() {
            return Err(format!("non-positive tolerance {}", self.tol));
        }
        match (self.fault_node, self.fault_apply) {
            (None, None) => {}
            (Some(node), Some(at)) => {
                if node >= self.nodes {
                    return Err(format!(
                        "fault_node {node} out of range for a {}-node cluster",
                        self.nodes
                    ));
                }
                if at == 0 {
                    return Err("fault_apply is 1-based; 0 never fires".into());
                }
            }
            _ => {
                return Err("fault_node and fault_apply must be given together".into());
            }
        }
        Ok(())
    }
}

/// Deterministic closed-loop workload: `count` requests round-robin over
/// `matrices`, every other field from `defaults`.
pub fn workload(matrices: &[String], count: usize, defaults: &RequestDefaults) -> Vec<SolveRequest> {
    if matrices.is_empty() {
        return Vec::new();
    }
    (0..count)
        .map(|i| SolveRequest::new(i, matrices[i % matrices.len()].clone(), defaults))
        .collect()
}

/// A value in a flat JSON trace line.
enum JsonValue {
    Str(String),
    Num(f64),
    Bool(bool),
    Null,
}

impl JsonValue {
    fn as_str(&self, key: &str) -> Result<&str, String> {
        match self {
            JsonValue::Str(s) => Ok(s),
            _ => Err(format!("field '{key}' must be a string")),
        }
    }

    fn as_f64(&self, key: &str) -> Result<f64, String> {
        match self {
            JsonValue::Num(v) => Ok(*v),
            _ => Err(format!("field '{key}' must be a number")),
        }
    }

    fn as_usize(&self, key: &str) -> Result<usize, String> {
        let v = self.as_f64(key)?;
        if v < 0.0 || v.fract() != 0.0 {
            return Err(format!("field '{key}' must be a non-negative integer, got {v}"));
        }
        Ok(v as usize)
    }
}

/// Character-cursor parser for one flat JSON object (no nesting).
struct Parser<'a> {
    c: &'a [char],
    i: usize,
}

impl Parser<'_> {
    fn peek(&self) -> Option<char> {
        self.c.get(self.i).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let ch = self.peek();
        if ch.is_some() {
            self.i += 1;
        }
        ch
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(' ' | '\t')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, want: char) -> Result<(), String> {
        match self.bump() {
            Some(ch) if ch == want => Ok(()),
            other => Err(format!("expected '{want}', found {other:?}")),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect('"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some('"') => return Ok(out),
                Some('\\') => match self.bump() {
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    Some('/') => out.push('/'),
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('r') => out.push('\r'),
                    Some('b') => out.push('\u{0008}'),
                    Some('f') => out.push('\u{000c}'),
                    Some('u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|ch| ch.to_digit(16))
                                .ok_or("bad \\u escape")?;
                            code = code * 16 + d;
                        }
                        out.push(char::from_u32(code).ok_or("bad \\u code point")?);
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                Some(ch) => out.push(ch),
            }
        }
    }

    fn literal(&mut self, word: &str) -> Result<(), String> {
        for want in word.chars() {
            if self.bump() != Some(want) {
                return Err(format!("bad literal (expected '{word}')"));
            }
        }
        Ok(())
    }

    fn value(&mut self) -> Result<JsonValue, String> {
        match self.peek() {
            Some('"') => Ok(JsonValue::Str(self.string()?)),
            Some('t') => {
                self.literal("true")?;
                Ok(JsonValue::Bool(true))
            }
            Some('f') => {
                self.literal("false")?;
                Ok(JsonValue::Bool(false))
            }
            Some('n') => {
                self.literal("null")?;
                Ok(JsonValue::Null)
            }
            Some(ch) if ch == '-' || ch == '+' || ch.is_ascii_digit() => {
                let start = self.i;
                while matches!(
                    self.peek(),
                    Some('-' | '+' | '.' | 'e' | 'E' | '0'..='9')
                ) {
                    self.i += 1;
                }
                let text: String = self.c[start..self.i].iter().collect();
                text.parse::<f64>().map(JsonValue::Num).map_err(|e| format!("bad number: {e}"))
            }
            other => Err(format!("expected a value, found {other:?}")),
        }
    }
}

/// Parse one trace line into (key, value) pairs.
fn parse_object(line: &str) -> Result<Vec<(String, JsonValue)>, String> {
    let chars: Vec<char> = line.chars().collect();
    let mut p = Parser { c: &chars, i: 0 };
    p.skip_ws();
    p.expect('{')?;
    let mut out = Vec::new();
    p.skip_ws();
    if p.peek() == Some('}') {
        p.i += 1;
    } else {
        loop {
            p.skip_ws();
            let key = p.string()?;
            p.skip_ws();
            p.expect(':')?;
            p.skip_ws();
            let val = p.value()?;
            out.push((key, val));
            p.skip_ws();
            match p.bump() {
                Some(',') => continue,
                Some('}') => break,
                other => return Err(format!("expected ',' or '}}', found {other:?}")),
            }
        }
    }
    p.skip_ws();
    if p.i != chars.len() {
        return Err("trailing characters after the object".into());
    }
    Ok(out)
}

/// Parse a JSONL trace into requests. Each non-empty, non-`#` line is a
/// flat JSON object; recognised fields are `matrix` (required),
/// `combo`, `partitioner`, `intra`, `format`, `solver`, `s_step`,
/// `tol`, `iters`, `nrhs`, `nodes`, `cores`, `seed`, `fault_node`,
/// `fault_apply`; anything else is an error (typos must not silently
/// fall back to defaults).
pub fn parse_trace(text: &str, defaults: &RequestDefaults) -> crate::Result<Vec<SolveRequest>> {
    let mut out: Vec<SolveRequest> = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields = parse_object(line)
            .map_err(|e| anyhow::anyhow!("trace line {}: {e}", lineno + 1))?;
        let mut req = SolveRequest::new(out.len(), String::new(), defaults);
        for (key, val) in &fields {
            let applied: Result<(), String> = match key.as_str() {
                "matrix" => val.as_str(key).map(|s| req.matrix = s.to_string()),
                "combo" => val.as_str(key).and_then(|s| {
                    Combination::parse(s)
                        .map(|c| req.combo = c)
                        .ok_or_else(|| format!("unknown combination '{s}'"))
                }),
                "partitioner" => val.as_str(key).and_then(|s| {
                    PartitionerKind::parse(s)
                        .map(|p| req.partitioner = p)
                        .ok_or_else(|| {
                            format!("unknown partitioner '{s}' ({})", PartitionerKind::usage())
                        })
                }),
                "intra" => val.as_str(key).and_then(|s| {
                    PartitionerKind::parse(s)
                        .map(|p| req.intra = p)
                        .ok_or_else(|| {
                            format!("unknown partitioner '{s}' ({})", PartitionerKind::usage())
                        })
                }),
                "format" => val.as_str(key).and_then(|s| {
                    FormatKind::parse(s)
                        .map(|f| req.format = f)
                        .ok_or_else(|| format!("unknown format '{s}'"))
                }),
                "solver" => val.as_str(key).and_then(|s| {
                    SolverKind::parse(s)
                        .map(|k| req.solver = k)
                        .ok_or_else(|| format!("unknown solver '{s}'"))
                }),
                "s_step" => val.as_usize(key).map(|v| req.s_step = v),
                "tol" => val.as_f64(key).map(|v| req.tol = v),
                "iters" => val.as_usize(key).map(|v| req.max_iters = v),
                "nrhs" => val.as_usize(key).map(|v| req.nrhs = v),
                "nodes" => val.as_usize(key).map(|v| req.nodes = v),
                "cores" => val.as_usize(key).map(|v| req.cores = v),
                "seed" => val.as_usize(key).map(|v| req.seed = v as u64),
                "fault_node" => val.as_usize(key).map(|v| req.fault_node = Some(v)),
                "fault_apply" => val.as_usize(key).map(|v| req.fault_apply = Some(v)),
                other => Err(format!("unknown field '{other}'")),
            };
            applied.map_err(|e| anyhow::anyhow!("trace line {}: {e}", lineno + 1))?;
        }
        anyhow::ensure!(!req.matrix.is_empty(), "trace line {}: missing 'matrix'", lineno + 1);
        out.push(req);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_mixed_trace_with_defaults_and_overrides() {
        let text = r#"
# service smoke corpus
{"matrix": "t2dal"}
{"matrix": "traces/bcsstm09.mtx", "solver": "jacobi", "nrhs": 4, "tol": 1e-6}

{"matrix": "spd", "combo": "nc-hl", "partitioner": "contig", "format": "ell", "iters": 50}
"#;
        let reqs = parse_trace(text, &RequestDefaults::default()).unwrap();
        assert_eq!(reqs.len(), 3);
        assert_eq!(reqs[0].matrix, "t2dal");
        assert_eq!(reqs[0].solver, SolverKind::Cg);
        assert_eq!(reqs[0].id, 0);
        assert_eq!(reqs[1].matrix, "traces/bcsstm09.mtx");
        assert_eq!(reqs[1].solver, SolverKind::Jacobi);
        assert_eq!(reqs[1].nrhs, 4);
        assert!((reqs[1].tol - 1e-6).abs() < 1e-18);
        assert_eq!(reqs[2].combo, Combination::NcHl);
        assert_eq!(reqs[2].partitioner, PartitionerKind::Contig);
        assert_eq!(reqs[2].format, FormatKind::Ell);
        assert_eq!(reqs[2].max_iters, 50);
        assert_eq!(reqs[2].id, 2);
    }

    #[test]
    fn rejects_typos_instead_of_defaulting() {
        let d = RequestDefaults::default();
        assert!(parse_trace(r#"{"matrix": "spd", "solvr": "cg"}"#, &d).is_err());
        assert!(parse_trace(r#"{"matrix": "spd", "solver": "cgg"}"#, &d).is_err());
        assert!(parse_trace(r#"{"solver": "cg"}"#, &d).is_err(), "matrix is required");
        assert!(parse_trace(r#"{"matrix": "spd" "#, &d).is_err(), "unclosed object");
        assert!(parse_trace(r#"{"matrix": "spd"} x"#, &d).is_err(), "trailing junk");
        assert!(parse_trace(r#"{"matrix": "spd", "nrhs": 1.5}"#, &d).is_err(), "non-integer");
    }

    #[test]
    fn fault_fields_parse_and_validate() {
        let d = RequestDefaults::default();
        let reqs = parse_trace(
            r#"{"matrix": "spd", "fault_node": 1, "fault_apply": 2}"#,
            &d,
        )
        .unwrap();
        assert_eq!(reqs[0].fault_node, Some(1));
        assert_eq!(reqs[0].fault_apply, Some(2));
        assert!(reqs[0].validate().is_ok());

        let mut r = reqs[0].clone();
        r.fault_node = Some(5); // defaults run 2 nodes
        assert!(r.validate().unwrap_err().contains("out of range"));

        let mut r = reqs[0].clone();
        r.fault_apply = Some(0);
        assert!(r.validate().unwrap_err().contains("1-based"));

        let mut r = reqs[0].clone();
        r.fault_apply = None;
        assert!(r.validate().unwrap_err().contains("together"));

        assert!(
            parse_trace(r#"{"matrix": "spd", "fault_node": 1.5, "fault_apply": 2}"#, &d)
                .is_err(),
            "non-integer fault_node"
        );
    }

    #[test]
    fn pipelined_solver_fields_parse_and_validate() {
        let d = RequestDefaults::default();
        let text = r#"
{"matrix": "spd", "solver": "pipelined-cg"}
{"matrix": "spd", "solver": "sstep-cg", "s_step": 2}
"#;
        let reqs = parse_trace(text, &d).unwrap();
        assert_eq!(reqs[0].solver, SolverKind::PipelinedCg);
        assert_eq!(reqs[0].s_step, 4, "default block size");
        assert_eq!(reqs[1].solver, SolverKind::SStepCg);
        assert_eq!(reqs[1].s_step, 2);
        assert!(reqs[1].validate().is_ok());
        let mut r = reqs[1].clone();
        r.s_step = 0;
        assert!(r.validate().unwrap_err().contains("s_step"));
    }

    #[test]
    fn string_escapes_round_trip() {
        let d = RequestDefaults::default();
        let reqs = parse_trace(r#"{"matrix": "dir\/aA b\t.mtx"}"#, &d).unwrap();
        assert_eq!(reqs[0].matrix, "dir/aA b\t.mtx");
    }

    #[test]
    fn workload_round_robins_deterministically() {
        let d = RequestDefaults::default();
        let ms = vec!["a".to_string(), "b".to_string(), "c".to_string()];
        let reqs = workload(&ms, 7, &d);
        assert_eq!(reqs.len(), 7);
        assert_eq!(reqs[0].matrix, "a");
        assert_eq!(reqs[3].matrix, "a");
        assert_eq!(reqs[5].matrix, "c");
        assert_eq!(reqs[6].id, 6);
        assert!(workload(&[], 5, &d).is_empty());
    }

    #[test]
    fn validation_rejects_unservable_combinations() {
        let d = RequestDefaults::default();
        let ok = SolveRequest::new(0, "t2dal".into(), &d);
        assert!(ok.validate().is_ok());

        let mut r = ok.clone();
        r.matrix = "no-such-matrix".into();
        assert!(r.validate().unwrap_err().contains("unknown matrix"));

        let mut r = ok.clone();
        r.partitioner = PartitionerKind::Fine2d;
        assert!(r.validate().unwrap_err().contains("2-D"));

        let mut r = ok.clone();
        r.nrhs = 4;
        r.solver = SolverKind::Power;
        assert!(r.validate().unwrap_err().contains("batched solver"));
        r.solver = SolverKind::Jacobi;
        assert!(r.validate().is_ok());

        let mut r = ok.clone();
        r.nrhs = 0;
        assert!(r.validate().is_err());

        let mut r = ok.clone();
        r.cores = 0;
        assert!(r.validate().is_err());

        let mut r = ok.clone();
        r.tol = 0.0;
        assert!(r.validate().is_err());

        let mut r = ok;
        r.max_iters = 0;
        assert!(r.validate().is_err());
    }
}
