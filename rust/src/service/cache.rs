//! Plan cache: decomposition + frozen `CommPlan`, LRU under a byte budget.
//!
//! The expensive half of a served solve is everything *before* the first
//! iteration: two-level decomposition and `CommPlan` freezing. The
//! [`PlanCache`] memoises that pair per [`PlanKey`] so repeat requests
//! for the same (matrix, combination, partitioner, format, shape) pay it
//! once. Entries are charged an estimated resident size and evicted
//! least-recently-used when the configured byte budget overflows — the
//! newest entry is always spared, so a budget smaller than one plan
//! degrades to "cache of one" rather than thrashing to zero. Eviction
//! only drops the cache's own `Arc` references; requests still solving
//! against an evicted plan keep it alive until they finish.

use super::fingerprint::PlanKey;
use crate::partition::combined::TwoLevelDecomposition;
use crate::pmvc::CommPlan;
use std::collections::HashMap;
use std::sync::Arc;

/// Per-key hit/miss/eviction counters for the service report.
#[derive(Clone, Copy, Debug, Default)]
pub struct KeyStats {
    /// Requests served from the cache.
    pub hits: usize,
    /// Requests that built the entry.
    pub misses: usize,
    /// Times the entry was evicted under the byte budget.
    pub evictions: usize,
}

struct Entry {
    d: Arc<TwoLevelDecomposition>,
    plan: Arc<CommPlan>,
    bytes: usize,
    last_used: u64,
}

/// LRU cache of decomposition + plan pairs under a byte budget.
pub struct PlanCache {
    budget: usize,
    entries: HashMap<PlanKey, Entry>,
    clock: u64,
    hits: usize,
    misses: usize,
    evictions: usize,
    per_key: HashMap<String, KeyStats>,
}

/// Estimated resident bytes of one cached entry: the fragments' CSR
/// arrays, their kernel storage when it is not CSR-in-place, the
/// global row/column maps, and the plan's footprint/assembly maps.
pub fn entry_bytes(d: &TwoLevelDecomposition, plan: &CommPlan) -> usize {
    let frag_bytes: usize = d
        .fragments
        .iter()
        .map(|fr| {
            let csr = 8 * (fr.csr.n_rows + 1) + 12 * fr.csr.nnz();
            let maps = 4 * (fr.global_rows.len() + fr.global_cols.len());
            let kernel = match fr.storage.kind() {
                crate::sparse::FormatKind::Csr => 0, // runs on `csr` in place
                _ => fr.stored_bytes(),
            };
            csr + maps + kernel
        })
        .sum();
    let plan_bytes: usize = plan
        .nodes
        .iter()
        .map(|np| {
            let per_core: usize = np
                .core_x_maps
                .iter()
                .chain(&np.core_y_maps)
                .chain(&np.core_interior_rows)
                .chain(&np.core_boundary_rows)
                .map(Vec::len)
                .sum();
            4 * (np.x_cols.len()
                + np.y_rows.len()
                + np.owned_x.len()
                + np.halo_x.len()
                + per_core)
        })
        .sum();
    frag_bytes + plan_bytes
}

impl PlanCache {
    /// Cache with room for roughly `budget` bytes of plans.
    pub fn new(budget: usize) -> Self {
        PlanCache {
            budget,
            entries: HashMap::new(),
            clock: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            per_key: HashMap::new(),
        }
    }

    /// Look up `key`, building (and inserting) on a miss via `build`.
    /// Returns the pair plus `true` on a hit. Holding the shared `Arc`s
    /// means an entry evicted later stays valid for in-flight solves.
    pub fn get_or_build(
        &mut self,
        key: &PlanKey,
        build: impl FnOnce() -> crate::Result<(Arc<TwoLevelDecomposition>, Arc<CommPlan>)>,
    ) -> crate::Result<(Arc<TwoLevelDecomposition>, Arc<CommPlan>, bool)> {
        self.clock += 1;
        if let Some(e) = self.entries.get_mut(key) {
            e.last_used = self.clock;
            self.hits += 1;
            self.per_key.entry(key.label()).or_default().hits += 1;
            return Ok((Arc::clone(&e.d), Arc::clone(&e.plan), true));
        }
        self.misses += 1;
        self.per_key.entry(key.label()).or_default().misses += 1;
        let (d, plan) = build()?;
        let bytes = entry_bytes(&d, &plan);
        let entry =
            Entry { d: Arc::clone(&d), plan: Arc::clone(&plan), bytes, last_used: self.clock };
        self.entries.insert(key.clone(), entry);
        self.evict_to_budget(key);
        Ok((d, plan, false))
    }

    /// Evict LRU entries (never `keep`) until the budget holds or only
    /// `keep` remains.
    fn evict_to_budget(&mut self, keep: &PlanKey) {
        while self.total_bytes() > self.budget && self.entries.len() > 1 {
            let victim = self
                .entries
                .iter()
                .filter(|(k, _)| *k != keep)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            let Some(k) = victim else { break };
            self.entries.remove(&k);
            self.evictions += 1;
            self.per_key.entry(k.label()).or_default().evictions += 1;
        }
    }

    /// Estimated resident bytes of all entries.
    pub fn total_bytes(&self) -> usize {
        self.entries.values().map(|e| e.bytes).sum()
    }

    /// Number of cached entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total cache hits.
    pub fn hits(&self) -> usize {
        self.hits
    }

    /// Total cache misses (entry builds).
    pub fn misses(&self) -> usize {
        self.misses
    }

    /// Total evictions under the byte budget.
    pub fn evictions(&self) -> usize {
        self.evictions
    }

    /// Per-key counters, labelled by [`PlanKey::label`].
    pub fn per_key(&self) -> &HashMap<String, KeyStats> {
        &self.per_key
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::combined::{decompose, Combination, DecomposeConfig};
    use crate::partition::PartitionerKind;
    use crate::sparse::{fingerprint_csr, FormatKind};

    fn build_pair(
        n: usize,
        seed: u64,
    ) -> (PlanKey, Arc<TwoLevelDecomposition>, Arc<CommPlan>) {
        let a = crate::sparse::gen::generate_spd(n, 3, n * 5, seed).to_csr();
        let key = PlanKey {
            fingerprint: fingerprint_csr(&a),
            combo: Combination::NlHl,
            inter: PartitionerKind::Nezgt,
            intra: PartitionerKind::Hypergraph,
            format: FormatKind::Csr,
            f: 2,
            c: 2,
        };
        let cfg = DecomposeConfig::default();
        let d = Arc::new(decompose(&a, key.combo, key.f, key.c, &cfg).unwrap());
        let plan = Arc::new(CommPlan::build(&d).unwrap());
        (key, d, plan)
    }

    #[test]
    fn hit_returns_the_same_arcs_without_rebuilding() {
        let (key, d, plan) = build_pair(120, 1);
        let mut cache = PlanCache::new(usize::MAX);
        let (d1, _, hit1) =
            cache.get_or_build(&key, || Ok((Arc::clone(&d), Arc::clone(&plan)))).unwrap();
        assert!(!hit1);
        let (d2, p2, hit2) = cache
            .get_or_build(&key, || panic!("hit must not rebuild"))
            .unwrap();
        assert!(hit2);
        assert!(Arc::ptr_eq(&d1, &d2));
        assert!(Arc::ptr_eq(&plan, &p2));
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.evictions(), 0);
    }

    #[test]
    fn lru_eviction_spares_the_newest_entry() {
        let (k1, d1, p1) = build_pair(100, 1);
        let (k2, d2, p2) = build_pair(100, 2);
        let (k3, d3, p3) = build_pair(100, 3);
        assert_ne!(k1, k2);
        let one = entry_bytes(&d1, &p1);
        // Budget fits ~two entries.
        let mut cache = PlanCache::new(2 * one + one / 2);
        cache.get_or_build(&k1, || Ok((d1, p1))).unwrap();
        cache.get_or_build(&k2, || Ok((d2, p2))).unwrap();
        assert_eq!(cache.len(), 2);
        // Touch k1 so k2 is the LRU victim when k3 arrives.
        cache.get_or_build(&k1, || panic!("cached")).unwrap();
        cache.get_or_build(&k3, || Ok((d3, p3))).unwrap();
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions(), 1);
        assert_eq!(cache.per_key()[&k2.label()].evictions, 1);
        // k1 and k3 survive.
        cache.get_or_build(&k1, || panic!("k1 evicted")).unwrap();
        cache.get_or_build(&k3, || panic!("k3 evicted")).unwrap();
    }

    #[test]
    fn tiny_budget_keeps_exactly_the_newest_entry() {
        let (k1, d1, p1) = build_pair(100, 1);
        let (k2, d2, p2) = build_pair(100, 2);
        let mut cache = PlanCache::new(1); // smaller than any plan
        cache.get_or_build(&k1, || Ok((d1, p1))).unwrap();
        assert_eq!(cache.len(), 1, "newest entry is spared");
        cache.get_or_build(&k2, || Ok((d2, p2))).unwrap();
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.evictions(), 1);
        // k2 is resident, k1 must rebuild.
        cache.get_or_build(&k2, || panic!("cached")).unwrap();
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn build_errors_do_not_poison_the_cache() {
        let (key, d, plan) = build_pair(100, 1);
        let mut cache = PlanCache::new(usize::MAX);
        let err = cache.get_or_build(&key, || anyhow::bail!("mtx file vanished"));
        assert!(err.is_err());
        assert_eq!(cache.len(), 0);
        // The next attempt can still succeed.
        let (_, _, hit) = cache.get_or_build(&key, || Ok((d, plan))).unwrap();
        assert!(!hit);
        assert_eq!(cache.misses(), 2);
    }
}
