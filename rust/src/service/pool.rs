//! Multiplexed pool of persistent `PmvcEngine`s.
//!
//! Spawning an engine means spawning f × c worker threads and shipping
//! them the frozen plan — worth amortising at least as much as the plan
//! itself. The [`EnginePool`] keeps up to `capacity` engines alive
//! (busy + idle combined, so the thread bill is bounded); a checkout for
//! a [`PlanKey`] reuses an idle engine warm on that key, builds a fresh
//! one if the pool has headroom, retires the least-recently-used idle
//! engine of another key to make room, or blocks until a slot frees.
//! Engines return to the idle set warm on
//! [`EnginePool::checkin`] — their scratch buffers and parked workers
//! survive to the next request.

use super::fingerprint::PlanKey;
use crate::pmvc::PmvcEngine;
use std::sync::{Condvar, Mutex};

/// Pool counters for the service report.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// Engines built (worker pools spawned).
    pub created: usize,
    /// Checkouts served by a warm idle engine.
    pub reused: usize,
    /// Idle engines retired to make room for another key.
    pub evicted: usize,
    /// Checked-out engines discarded as broken (dead rank) instead of
    /// returned warm.
    pub discarded: usize,
    /// High-water mark of live engines (never exceeds the capacity).
    pub peak_live: usize,
}

struct IdleEngine {
    key: PlanKey,
    engine: PmvcEngine,
    last_used: u64,
}

struct PoolInner {
    idle: Vec<IdleEngine>,
    /// Engines alive right now: checked out + idle.
    live: usize,
    clock: u64,
    stats: PoolStats,
}

enum Checkout {
    Reuse(PmvcEngine),
    /// Slot reserved; carries an evicted idle engine to drop outside
    /// the lock (dropping joins its worker threads).
    Build(Option<PmvcEngine>),
}

/// Bounded pool of warm engines, keyed by the plan they were built for.
pub struct EnginePool {
    capacity: usize,
    inner: Mutex<PoolInner>,
    available: Condvar,
}

impl EnginePool {
    /// Pool bounded at `capacity` live engines (floored at 1).
    pub fn new(capacity: usize) -> Self {
        EnginePool {
            capacity: capacity.max(1),
            inner: Mutex::new(PoolInner {
                idle: Vec::new(),
                live: 0,
                clock: 0,
                stats: PoolStats::default(),
            }),
            available: Condvar::new(),
        }
    }

    /// The configured bound on live engines.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Snapshot of the pool counters.
    pub fn stats(&self) -> PoolStats {
        self.inner.lock().unwrap().stats
    }

    /// Engines alive right now (checked out + idle).
    pub fn live(&self) -> usize {
        self.inner.lock().unwrap().live
    }

    /// Check out an engine for `key`: a warm idle engine when one
    /// matches (returns `(engine, true)`), otherwise a fresh one from
    /// `build` (`(engine, false)`), evicting the LRU idle engine of
    /// another key or blocking for a slot when the pool is at capacity.
    /// `build` runs outside the pool lock; on error the reserved slot is
    /// released, so a failed build never wedges other requests.
    pub fn checkout(
        &self,
        key: &PlanKey,
        build: impl FnOnce() -> crate::Result<PmvcEngine>,
    ) -> crate::Result<(PmvcEngine, bool)> {
        let action = {
            let mut inner = self.inner.lock().unwrap();
            loop {
                if let Some(pos) = inner.idle.iter().position(|e| e.key == *key) {
                    let idle = inner.idle.swap_remove(pos);
                    inner.stats.reused += 1;
                    break Checkout::Reuse(idle.engine);
                }
                if inner.live < self.capacity {
                    inner.live += 1;
                    inner.stats.created += 1;
                    inner.stats.peak_live = inner.stats.peak_live.max(inner.live);
                    break Checkout::Build(None);
                }
                if !inner.idle.is_empty() {
                    let pos = inner
                        .idle
                        .iter()
                        .enumerate()
                        .min_by_key(|(_, e)| e.last_used)
                        .map(|(i, _)| i)
                        .unwrap();
                    let victim = inner.idle.swap_remove(pos);
                    inner.stats.evicted += 1;
                    // The victim's slot transfers straight to this
                    // request: live count is unchanged (one retired, one
                    // being built), and stays <= capacity throughout.
                    inner.stats.created += 1;
                    break Checkout::Build(Some(victim.engine));
                }
                // Every engine is checked out; wait for a checkin.
                inner = self.available.wait(inner).unwrap();
            }
        };
        match action {
            Checkout::Reuse(engine) => Ok((engine, true)),
            Checkout::Build(victim) => {
                // Joining the evicted engine's workers happens here,
                // outside the lock.
                drop(victim);
                match build() {
                    Ok(engine) => Ok((engine, false)),
                    Err(err) => {
                        let mut inner = self.inner.lock().unwrap();
                        inner.live -= 1;
                        drop(inner);
                        self.available.notify_one();
                        Err(err)
                    }
                }
            }
        }
    }

    /// Return an engine to the idle set, warm for the next checkout of
    /// the same key.
    pub fn checkin(&self, key: PlanKey, engine: PmvcEngine) {
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let last_used = inner.clock;
        inner.idle.push(IdleEngine { key, engine, last_used });
        drop(inner);
        self.available.notify_one();
    }

    /// Drop a checked-out engine that is no longer trustworthy (a rank
    /// died inside it) instead of returning it warm: its workers are
    /// joined outside the lock and the slot is released, exactly like a
    /// failed build, so a replacement can be built immediately.
    pub fn discard(&self, engine: PmvcEngine) {
        // joining the broken engine's surviving workers happens here,
        // outside the lock
        drop(engine);
        let mut inner = self.inner.lock().unwrap();
        inner.live -= 1;
        inner.stats.discarded += 1;
        drop(inner);
        self.available.notify_one();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::combined::{decompose, Combination, DecomposeConfig};
    use crate::partition::PartitionerKind;
    use crate::pmvc::CommPlan;
    use crate::sparse::{fingerprint_csr, FormatKind};
    use std::sync::Arc;

    fn key_and_engine(seed: u64) -> (PlanKey, impl Fn() -> crate::Result<PmvcEngine>) {
        let a = crate::sparse::gen::generate_spd(80, 3, 400, seed).to_csr();
        let key = PlanKey {
            fingerprint: fingerprint_csr(&a),
            combo: Combination::NlHl,
            inter: PartitionerKind::Nezgt,
            intra: PartitionerKind::Hypergraph,
            format: FormatKind::Csr,
            f: 2,
            c: 2,
        };
        let d = Arc::new(decompose(&a, key.combo, 2, 2, &DecomposeConfig::default()).unwrap());
        let plan = Arc::new(CommPlan::build(&d).unwrap());
        (key, move || PmvcEngine::with_plan(Arc::clone(&d), Arc::clone(&plan)))
    }

    #[test]
    fn checkin_then_checkout_reuses_the_warm_engine() {
        let (key, build) = key_and_engine(1);
        let pool = EnginePool::new(2);
        let (engine, reused) = pool.checkout(&key, &build).unwrap();
        assert!(!reused);
        assert_eq!(engine.plan_builds(), 0, "with_plan engines never rebuild the plan");
        pool.checkin(key.clone(), engine);
        let (engine, reused) = pool.checkout(&key, || panic!("must reuse")).unwrap();
        assert!(reused);
        pool.checkin(key, engine);
        let s = pool.stats();
        assert_eq!((s.created, s.reused, s.evicted, s.peak_live), (1, 1, 0, 1));
    }

    #[test]
    fn full_pool_evicts_the_lru_idle_engine_of_another_key() {
        let (k1, b1) = key_and_engine(1);
        let (k2, b2) = key_and_engine(2);
        let (k3, b3) = key_and_engine(3);
        let pool = EnginePool::new(2);
        let e1 = pool.checkout(&k1, &b1).unwrap().0;
        let e2 = pool.checkout(&k2, &b2).unwrap().0;
        pool.checkin(k1.clone(), e1); // k1 idles first -> LRU
        pool.checkin(k2.clone(), e2);
        let (e3, reused) = pool.checkout(&k3, &b3).unwrap();
        assert!(!reused);
        let s = pool.stats();
        assert_eq!(s.evicted, 1);
        assert_eq!(s.peak_live, 2);
        assert_eq!(pool.live(), 2);
        // k2 survived, k1 was the victim.
        let (e2, reused) = pool.checkout(&k2, || panic!("k2 was evicted")).unwrap();
        assert!(reused);
        pool.checkin(k2, e2);
        pool.checkin(k3, e3);
    }

    #[test]
    fn discard_releases_the_slot_and_counts() {
        let (k1, b1) = key_and_engine(4);
        let pool = EnginePool::new(1);
        let (engine, _) = pool.checkout(&k1, &b1).unwrap();
        pool.discard(engine);
        assert_eq!(pool.live(), 0, "the discarded engine's slot is free");
        assert_eq!(pool.stats().discarded, 1);
        // a replacement builds immediately instead of blocking
        let (engine, reused) = pool.checkout(&k1, &b1).unwrap();
        assert!(!reused, "the broken engine must not be reused");
        pool.checkin(k1, engine);
    }

    #[test]
    fn failed_build_releases_the_reserved_slot() {
        let (k1, b1) = key_and_engine(1);
        let pool = EnginePool::new(1);
        let err = pool.checkout(&k1, || anyhow::bail!("no such plan"));
        assert!(err.is_err());
        assert_eq!(pool.live(), 0);
        // The slot is free again.
        let (engine, _) = pool.checkout(&k1, &b1).unwrap();
        pool.checkin(k1, engine);
    }

    #[test]
    fn checkout_blocks_until_a_busy_engine_returns() {
        let (key, build) = key_and_engine(1);
        let pool = Arc::new(EnginePool::new(1));
        let (engine, _) = pool.checkout(&key, &build).unwrap();
        let pool2 = Arc::clone(&pool);
        let key2 = key.clone();
        let waiter = std::thread::spawn(move || {
            let (engine, reused) = pool2.checkout(&key2, || panic!("capacity 1")).unwrap();
            pool2.checkin(key2, engine);
            reused
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        pool.checkin(key, engine);
        assert!(waiter.join().unwrap(), "the returned engine is reused warm");
        assert_eq!(pool.stats().peak_live, 1);
    }
}
