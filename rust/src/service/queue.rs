//! Bounded admission queue with typed rejection.
//!
//! Requests enter the service through an [`AdmissionQueue`]: a fixed-depth
//! MPMC queue guarded by a mutex and two condition variables. Producers
//! either block until a slot frees ([`AdmissionQueue::push`], the
//! closed-loop client posture) or take a typed
//! [`AdmitError::QueueFull`] rejection immediately
//! ([`AdmissionQueue::try_push`], the open-loop posture). Consumers
//! ([`AdmissionQueue::pop`]) block until an item or shutdown arrives;
//! after [`AdmissionQueue::close`] they drain the backlog and then see
//! `None`, so no admitted request is ever dropped on shutdown.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Typed admission outcome for a request that was not accepted.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AdmitError {
    /// The queue was at capacity (open-loop submission only).
    QueueFull {
        /// The configured queue depth that was exhausted.
        capacity: usize,
    },
    /// The request described an invalid combination (bad solver/format
    /// pairing, unknown matrix, zero-sized panel, ...). Raised by request
    /// validation before the queue is involved.
    Invalid {
        /// Human-readable reason, surfaced in the service report.
        reason: String,
    },
    /// The queue was closed; the service is shutting down.
    Closed,
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::QueueFull { capacity } => {
                write!(f, "admission queue full (depth {capacity})")
            }
            AdmitError::Invalid { reason } => write!(f, "invalid request: {reason}"),
            AdmitError::Closed => write!(f, "admission queue closed"),
        }
    }
}

impl std::error::Error for AdmitError {}

struct Inner<T> {
    q: VecDeque<T>,
    closed: bool,
}

/// Bounded multi-producer multi-consumer queue in front of the workers.
pub struct AdmissionQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

impl<T> AdmissionQueue<T> {
    /// Queue with room for `capacity` pending items (floored at 1).
    pub fn new(capacity: usize) -> Self {
        AdmissionQueue {
            inner: Mutex::new(Inner { q: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// The configured depth.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Items currently waiting.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    /// True when no items are waiting.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Admit `item`, blocking while the queue is full (closed-loop
    /// backpressure). Fails only with [`AdmitError::Closed`].
    pub fn push(&self, item: T) -> Result<(), AdmitError> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if inner.closed {
                return Err(AdmitError::Closed);
            }
            if inner.q.len() < self.capacity {
                inner.q.push_back(item);
                drop(inner);
                self.not_empty.notify_one();
                return Ok(());
            }
            inner = self.not_full.wait(inner).unwrap();
        }
    }

    /// Admit `item` without blocking. A full queue yields the typed
    /// [`AdmitError::QueueFull`] rejection (and drops the item — callers
    /// record the rejection from fields captured beforehand).
    pub fn try_push(&self, item: T) -> Result<(), AdmitError> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return Err(AdmitError::Closed);
        }
        if inner.q.len() >= self.capacity {
            return Err(AdmitError::QueueFull { capacity: self.capacity });
        }
        inner.q.push_back(item);
        drop(inner);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Take the oldest item, blocking while the queue is empty and open.
    /// Returns `None` once the queue is closed *and* drained — admitted
    /// work is never dropped.
    pub fn pop(&self) -> Option<T> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(item) = inner.q.pop_front() {
                drop(inner);
                self.not_full.notify_one();
                return Some(item);
            }
            if inner.closed {
                return None;
            }
            inner = self.not_empty.wait(inner).unwrap();
        }
    }

    /// Close admission: pending producers fail with
    /// [`AdmitError::Closed`]; consumers drain the backlog then stop.
    pub fn close(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.closed = true;
        drop(inner);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn try_push_rejects_when_full_with_typed_error() {
        let q = AdmissionQueue::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.try_push(3), Err(AdmitError::QueueFull { capacity: 2 }));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        q.try_push(3).unwrap();
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    fn close_drains_backlog_then_stops_consumers() {
        let q = AdmissionQueue::new(4);
        q.push(10).unwrap();
        q.push(11).unwrap();
        q.close();
        assert_eq!(q.push(12), Err(AdmitError::Closed));
        assert_eq!(q.try_push(12), Err(AdmitError::Closed));
        assert_eq!(q.pop(), Some(10));
        assert_eq!(q.pop(), Some(11));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn blocking_push_waits_for_a_slot() {
        let q = Arc::new(AdmissionQueue::new(1));
        q.push(1).unwrap();
        let q2 = Arc::clone(&q);
        let producer = std::thread::spawn(move || q2.push(2));
        // Let the producer reach the wait, then free a slot.
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(q.pop(), Some(1));
        producer.join().unwrap().unwrap();
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn pop_blocks_until_an_item_arrives() {
        let q = Arc::new(AdmissionQueue::new(2));
        let q2 = Arc::clone(&q);
        let consumer = std::thread::spawn(move || q2.pop());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.push(7).unwrap();
        assert_eq!(consumer.join().unwrap(), Some(7));
    }

    #[test]
    fn errors_render_their_reason() {
        let e = AdmitError::QueueFull { capacity: 8 };
        assert!(e.to_string().contains("depth 8"));
        let e = AdmitError::Invalid { reason: "nrhs 0".into() };
        assert!(e.to_string().contains("nrhs 0"));
        assert!(AdmitError::Closed.to_string().contains("closed"));
    }
}
