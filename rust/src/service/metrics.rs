//! `ServiceReport`: the serving-side companion of `PhaseTimes`.
//!
//! Where `PhaseTimes` decomposes one matvec into scatter / compute /
//! gather, the [`ServiceReport`] decomposes a whole served session:
//! admission outcomes, plan-cache effectiveness, engine-pool reuse,
//! queue-wait and end-to-end latency percentiles, and throughput in
//! solves/sec and matvecs/sec. It renders as a fixed-width table for the
//! terminal and as a flat JSON object for dashboards; the raw per-request
//! [`RequestOutcome`]s ride along for tests and offline analysis.

/// Terminal state of one request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RequestStatus {
    /// Admitted, solved, reported.
    Completed,
    /// Admitted, the first solve died with the engine (injected rank
    /// death), and the retry on a rebuilt engine solved it — served,
    /// not dropped.
    Recovered,
    /// Admitted but the solve errored (reason attached).
    Failed(String),
    /// Rejected at admission: queue at capacity (open-loop mode).
    RejectedFull,
    /// Rejected at admission: invalid combination (reason attached).
    RejectedInvalid(String),
}

/// What happened to one request, echoed with its trace id.
#[derive(Clone, Debug)]
pub struct RequestOutcome {
    /// Request id from the trace / workload.
    pub id: usize,
    /// Matrix source of the request.
    pub matrix: String,
    /// Terminal state.
    pub status: RequestStatus,
    /// Whether the plan came from the cache.
    pub cache_hit: bool,
    /// Whether the engine was a warm pool reuse.
    pub engine_reused: bool,
    /// Seconds between admission and a worker picking the request up.
    pub queue_wait_s: f64,
    /// Seconds between admission and the outcome (end-to-end).
    pub latency_s: f64,
    /// Solver iterations (max over panel columns for `nrhs > 1`).
    pub iterations: usize,
    /// Solver convergence flag (all columns for `nrhs > 1`).
    pub converged: bool,
    /// Distributed matvec applications performed (panel column count ×
    /// panel applies for batched solves).
    pub matvecs: usize,
    /// The plan-cache key label this request resolved to (empty for
    /// rejections).
    pub key_label: String,
    /// The solution panel, kept only when the service runs with
    /// `keep_solutions` (tests); `None` otherwise.
    pub x: Option<Vec<f64>>,
}

impl RequestOutcome {
    /// True when the request was admitted and solved first try.
    pub fn is_completed(&self) -> bool {
        self.status == RequestStatus::Completed
    }

    /// True when the request was served an answer — first try or after
    /// an engine-rebuild retry.
    pub fn is_served(&self) -> bool {
        matches!(self.status, RequestStatus::Completed | RequestStatus::Recovered)
    }
}

/// Per-cache-key counters surfaced in the report.
#[derive(Clone, Debug)]
pub struct KeyReport {
    /// [`super::fingerprint::PlanKey::label`] of the entry.
    pub key: String,
    /// Cache hits on this key.
    pub hits: usize,
    /// Cache misses (builds) on this key.
    pub misses: usize,
    /// Times this key was evicted under the byte budget.
    pub evictions: usize,
}

/// Aggregated serving metrics for one service session.
#[derive(Clone, Debug)]
pub struct ServiceReport {
    /// Requests solved first try.
    pub completed: usize,
    /// Requests served after their engine died and was rebuilt.
    pub recovered: usize,
    /// Requests admitted whose solve errored.
    pub failed: usize,
    /// Typed queue-full rejections.
    pub rejected_full: usize,
    /// Typed invalid-combination rejections.
    pub rejected_invalid: usize,
    /// Plan-cache hits.
    pub cache_hits: usize,
    /// Plan-cache misses (decompose + plan builds).
    pub cache_misses: usize,
    /// Plan-cache evictions under the byte budget.
    pub cache_evictions: usize,
    /// Estimated resident bytes of the cache at shutdown.
    pub cache_bytes: usize,
    /// Engines built by the pool.
    pub engines_created: usize,
    /// Checkouts served warm.
    pub engines_reused: usize,
    /// Idle engines retired to make room.
    pub engines_evicted: usize,
    /// Broken engines discarded after a rank death.
    pub engines_discarded: usize,
    /// High-water mark of live engines.
    pub engine_peak: usize,
    /// Median queue wait, milliseconds.
    pub queue_wait_p50_ms: f64,
    /// 95th-percentile queue wait, milliseconds.
    pub queue_wait_p95_ms: f64,
    /// Median end-to-end latency, milliseconds.
    pub latency_p50_ms: f64,
    /// 95th-percentile end-to-end latency, milliseconds.
    pub latency_p95_ms: f64,
    /// Wall-clock seconds of the whole session.
    pub wall_s: f64,
    /// Completed solves per second of wall clock.
    pub solves_per_sec: f64,
    /// Distributed matvec applications per second of wall clock.
    pub matvecs_per_sec: f64,
    /// Per-key cache counters, most-used first.
    pub per_key: Vec<KeyReport>,
    /// Raw per-request outcomes (trace order not guaranteed).
    pub outcomes: Vec<RequestOutcome>,
}

/// Nearest-rank percentile of an ascending-sorted slice (0 when empty).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = (p / 100.0) * (sorted.len() - 1) as f64;
    let idx = (pos.round() as usize).min(sorted.len() - 1);
    sorted[idx]
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

impl ServiceReport {
    /// Fraction of plan lookups served from the cache (0 when none).
    pub fn hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Requests that reached a terminal state (completed + recovered +
    /// failed + rejected) — the accounting identity the tests pin
    /// against the submitted count: nothing dropped, nothing wedged.
    pub fn accounted(&self) -> usize {
        self.completed + self.recovered + self.failed + self.rejected_full + self.rejected_invalid
    }

    /// Fixed-width terminal table.
    pub fn table(&self) -> String {
        let mut t = String::new();
        t.push_str("service report\n");
        t.push_str(
            "--------------------------------------------------------------------------\n",
        );
        t.push_str(&format!(
            "requests     completed={} recovered={} failed={} rejected(queue-full)={} rejected(invalid)={}\n",
            self.completed, self.recovered, self.failed, self.rejected_full, self.rejected_invalid
        ));
        t.push_str(&format!(
            "plan cache   hits={} misses={} hit-rate={:.1}% evictions={} resident={} B\n",
            self.cache_hits,
            self.cache_misses,
            100.0 * self.hit_rate(),
            self.cache_evictions,
            self.cache_bytes
        ));
        t.push_str(&format!(
            "engine pool  created={} reused={} evicted={} discarded={} peak-live={}\n",
            self.engines_created,
            self.engines_reused,
            self.engines_evicted,
            self.engines_discarded,
            self.engine_peak
        ));
        t.push_str(&format!(
            "queue wait   p50={:.3} ms  p95={:.3} ms\n",
            self.queue_wait_p50_ms, self.queue_wait_p95_ms
        ));
        t.push_str(&format!(
            "latency      p50={:.3} ms  p95={:.3} ms (admission -> solution)\n",
            self.latency_p50_ms, self.latency_p95_ms
        ));
        t.push_str(&format!(
            "throughput   {:.2} solves/s  {:.1} matvecs/s  over {:.3} s wall\n",
            self.solves_per_sec, self.matvecs_per_sec, self.wall_s
        ));
        if !self.per_key.is_empty() {
            t.push_str("per-key      hits  misses  evict  key\n");
            for k in &self.per_key {
                t.push_str(&format!(
                    "             {:>4}  {:>6}  {:>5}  {}\n",
                    k.hits, k.misses, k.evictions, k.key
                ));
            }
        }
        t
    }

    /// Flat JSON object with the aggregate metrics and the per-key
    /// counter list (per-request outcomes are not serialised).
    pub fn to_json(&self) -> String {
        let mut keys = String::new();
        for (i, k) in self.per_key.iter().enumerate() {
            if i > 0 {
                keys.push_str(",\n");
            }
            keys.push_str(&format!(
                "    {{\"key\": \"{}\", \"hits\": {}, \"misses\": {}, \"evictions\": {}}}",
                json_escape(&k.key),
                k.hits,
                k.misses,
                k.evictions
            ));
        }
        format!(
            "{{\n  \"completed\": {},\n  \"recovered\": {},\n  \"failed\": {},\n  \
             \"rejected_full\": {},\n  \
             \"rejected_invalid\": {},\n  \"cache_hits\": {},\n  \"cache_misses\": {},\n  \
             \"cache_evictions\": {},\n  \"cache_bytes\": {},\n  \"hit_rate\": {:.6},\n  \
             \"engines_created\": {},\n  \"engines_reused\": {},\n  \"engines_evicted\": {},\n  \
             \"engines_discarded\": {},\n  \
             \"engine_peak\": {},\n  \"queue_wait_p50_ms\": {:.6},\n  \
             \"queue_wait_p95_ms\": {:.6},\n  \"latency_p50_ms\": {:.6},\n  \
             \"latency_p95_ms\": {:.6},\n  \"wall_s\": {:.6},\n  \"solves_per_sec\": {:.3},\n  \
             \"matvecs_per_sec\": {:.3},\n  \"per_key\": [\n{}\n  ]\n}}\n",
            self.completed,
            self.recovered,
            self.failed,
            self.rejected_full,
            self.rejected_invalid,
            self.cache_hits,
            self.cache_misses,
            self.cache_evictions,
            self.cache_bytes,
            self.hit_rate(),
            self.engines_created,
            self.engines_reused,
            self.engines_evicted,
            self.engines_discarded,
            self.engine_peak,
            self.queue_wait_p50_ms,
            self.queue_wait_p95_ms,
            self.latency_p50_ms,
            self.latency_p95_ms,
            self.wall_s,
            self.solves_per_sec,
            self.matvecs_per_sec,
            keys
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ServiceReport {
        ServiceReport {
            completed: 17,
            recovered: 1,
            failed: 0,
            rejected_full: 1,
            rejected_invalid: 2,
            cache_hits: 15,
            cache_misses: 3,
            cache_evictions: 1,
            cache_bytes: 123_456,
            engines_created: 3,
            engines_reused: 15,
            engines_evicted: 0,
            engines_discarded: 1,
            engine_peak: 3,
            queue_wait_p50_ms: 0.4,
            queue_wait_p95_ms: 1.9,
            latency_p50_ms: 11.5,
            latency_p95_ms: 30.25,
            wall_s: 0.5,
            solves_per_sec: 36.0,
            matvecs_per_sec: 7200.0,
            per_key: vec![KeyReport {
                key: "862ade9f/NL-HL/nezgt+hypergraph/csr/2x2".into(),
                hits: 15,
                misses: 3,
                evictions: 1,
            }],
            outcomes: Vec::new(),
        }
    }

    #[test]
    fn percentile_is_nearest_rank() {
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[3.0], 95.0), 3.0);
        let v = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 50.0), 6.0); // round(4.5) = 5 -> v[5]
        assert_eq!(percentile(&v, 95.0), 10.0);
        assert_eq!(percentile(&v, 100.0), 10.0);
    }

    #[test]
    fn hit_rate_and_accounting() {
        let r = sample();
        assert!((r.hit_rate() - 15.0 / 18.0).abs() < 1e-12);
        assert_eq!(r.accounted(), 21);
        let empty = ServiceReport { cache_hits: 0, cache_misses: 0, ..sample() };
        assert_eq!(empty.hit_rate(), 0.0);
    }

    #[test]
    fn json_contains_the_acceptance_keys() {
        let json = sample().to_json();
        for key in [
            "\"hit_rate\"",
            "\"latency_p50_ms\"",
            "\"latency_p95_ms\"",
            "\"solves_per_sec\"",
            "\"queue_wait_p95_ms\"",
            "\"matvecs_per_sec\"",
            "\"per_key\"",
            "\"recovered\"",
            "\"engines_discarded\"",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        assert!(json.contains("\"hit_rate\": 0.833333"));
        assert!(json.contains("862ade9f/NL-HL"));
    }

    #[test]
    fn json_escapes_path_keys() {
        let mut r = sample();
        r.per_key[0].key = "dir\\weird\"name.mtx".into();
        let json = r.to_json();
        assert!(json.contains("dir\\\\weird\\\"name.mtx"));
    }

    #[test]
    fn table_lists_every_section() {
        let t = sample().table();
        for needle in
            ["requests", "plan cache", "engine pool", "queue wait", "latency", "throughput"]
        {
            assert!(t.contains(needle), "missing {needle}");
        }
        assert!(t.contains("hit-rate=83.3%"));
        assert!(t.contains("recovered=1"));
        assert!(t.contains("discarded=1"));
        assert!(t.contains("per-key"));
    }
}
