//! Solve-as-a-service: a persistent coordinator serving solve requests.
//!
//! The sweep driver amortises nothing: every cell pays partitioning,
//! plan freezing and engine spawn from scratch. This module is the
//! serving posture instead — one long-lived coordinator multiplexing a
//! stream of solve requests over shared infrastructure:
//!
//! - [`trace`] — the request model: a [`SolveRequest`] names a matrix
//!   source, a partitioner/format/solver combination and an `nrhs`-wide
//!   RHS panel; parsed from a JSONL trace file or synthesised by the
//!   built-in closed-loop workload generator;
//! - [`queue`] — bounded admission with typed rejection
//!   ([`AdmitError`]): full queue and invalid combination are first-class
//!   outcomes, not panics;
//! - [`fingerprint`] — the cache identity: a structural
//!   [`crate::sparse::MatrixFingerprint`] × combination × partitioners ×
//!   format × (f, c) shape, as a hashable [`PlanKey`];
//! - [`cache`] — the [`PlanCache`]: decomposition + frozen `CommPlan`
//!   pairs, LRU-evicted under a byte budget;
//! - [`pool`] — the [`EnginePool`]: persistent `PmvcEngine`s checked
//!   out per request and returned warm, bounding live worker threads;
//! - [`server`] — [`run_service`]: clients → queue → workers → report;
//! - [`metrics`] — the [`ServiceReport`]: hit rates, queue-wait and
//!   end-to-end latency percentiles, solves/sec and matvecs/sec,
//!   per-key counters; rendered as a table or JSON.

pub mod cache;
pub mod fingerprint;
pub mod metrics;
pub mod pool;
pub mod queue;
pub mod server;
pub mod trace;

pub use cache::{entry_bytes, KeyStats, PlanCache};
pub use fingerprint::PlanKey;
pub use metrics::{KeyReport, RequestOutcome, RequestStatus, ServiceReport};
pub use pool::{EnginePool, PoolStats};
pub use queue::{AdmissionQueue, AdmitError};
pub use server::{one_shot_solution, rhs_panel, run_service, ServeConfig};
pub use trace::{parse_trace, workload, RequestDefaults, SolveRequest};
