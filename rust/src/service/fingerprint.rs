//! Cache keys: matrix content × decomposition recipe.
//!
//! A [`PlanKey`] identifies everything that determines a
//! `TwoLevelDecomposition` + `CommPlan` pair: the structural
//! [`MatrixFingerprint`] of the operator (so the same matrix reached by
//! name or by MatrixMarket ingest shares an entry), the inter/intra
//! [`Combination`], the concrete partitioner pair, the storage
//! [`FormatKind`], and the cluster shape (f nodes × c cores). Two
//! requests with equal keys can share a cached plan and a warm engine;
//! anything differing forces a rebuild.

use crate::partition::combined::Combination;
use crate::partition::PartitionerKind;
use crate::sparse::{FormatKind, MatrixFingerprint};

/// Identity of one cacheable decomposition + plan.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// Structural fingerprint of the operator.
    pub fingerprint: MatrixFingerprint,
    /// Inter/intra axis combination.
    pub combo: Combination,
    /// Inter-node partitioner.
    pub inter: PartitionerKind,
    /// Intra-node partitioner.
    pub intra: PartitionerKind,
    /// Per-fragment storage selection.
    pub format: FormatKind,
    /// Nodes.
    pub f: usize,
    /// Cores per node.
    pub c: usize,
}

impl PlanKey {
    /// Compact human-readable tag for report tables, e.g.
    /// `862ade9f/NL-HL/nezgt+hypergraph/csr/2x2`.
    pub fn label(&self) -> String {
        format!(
            "{}/{}/{}+{}/{}/{}x{}",
            self.fingerprint.short(),
            self.combo.name(),
            self.inter.name(),
            self.intra.name(),
            self.format.name(),
            self.f,
            self.c
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{fingerprint_coo, Coo};

    fn key(format: FormatKind) -> PlanKey {
        let m = Coo::from_triplets(2, 2, [(0, 0, 1.0), (0, 1, 2.0), (1, 1, 3.0)]).unwrap();
        PlanKey {
            fingerprint: fingerprint_coo(&m),
            combo: Combination::NlHl,
            inter: PartitionerKind::Nezgt,
            intra: PartitionerKind::Hypergraph,
            format,
            f: 2,
            c: 2,
        }
    }

    #[test]
    fn label_names_every_dimension() {
        let label = key(FormatKind::Csr).label();
        assert_eq!(label, "862ade9f/NL-HL/nezgt+hypergraph/csr/2x2");
    }

    #[test]
    fn format_is_part_of_the_key() {
        assert_ne!(key(FormatKind::Csr), key(FormatKind::Ell));
        assert_eq!(key(FormatKind::Csr), key(FormatKind::Csr));
    }
}
