//! The service loop: clients → admission queue → workers → report.
//!
//! [`run_service`] wires the subsystem together for one session:
//!
//! - **client threads** drain the request list closed-loop (submit one,
//!   wait for its outcome, submit the next — the paper's "many
//!   concurrent users" shape). Invalid requests are rejected typed
//!   before touching the queue; with
//!   [`ServeConfig::reject_when_full`] a full queue rejects typed
//!   instead of exerting backpressure;
//! - **worker threads** pop requests, resolve the matrix (memoised per
//!   source × seed, fingerprinted once), take the decomposition + frozen
//!   plan from the [`PlanCache`], check a warm [`PmvcEngine`] out of the
//!   [`EnginePool`], run the request's solver over its `nrhs`-wide RHS
//!   panel in one batched solve, and return the engine warm;
//! - the main thread joins everything, drains the outcomes and folds
//!   them into a [`ServiceReport`].
//!
//! Every path is panic-free: a request that fails (missing `.mtx` file,
//! singular diagonal, ...) reports `Failed` and the session keeps
//! serving. [`one_shot_solution`] is the reference path — the same
//! solve without queue, cache or pool — used by the tests to pin
//! served answers at 1e-9.

use super::cache::PlanCache;
use super::fingerprint::PlanKey;
use super::metrics::{percentile, KeyReport, RequestOutcome, RequestStatus, ServiceReport};
use super::pool::EnginePool;
use super::queue::{AdmissionQueue, AdmitError};
use super::trace::SolveRequest;
use crate::coordinator::experiment::load_matrix;
use crate::partition::combined::{decompose, DecomposeConfig, TwoLevelDecomposition};
use crate::pmvc::{CommPlan, FaultPlan, PmvcEngine};
use crate::solver::{make_solver_with, BatchedJacobi, BlockCg, MatVecOp, MultiVecOp, SolverKind};
use crate::sparse::{fingerprint_csr, Csr, MatrixFingerprint};
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Service-session knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Admission queue depth.
    pub queue_depth: usize,
    /// Engine-pool capacity (live engines, busy + idle).
    pub engines: usize,
    /// Worker threads consuming the queue.
    pub workers: usize,
    /// Client threads submitting requests.
    pub clients: usize,
    /// Plan-cache byte budget.
    pub cache_bytes: usize,
    /// Disable to rebuild decomposition + plan + engine per request
    /// (the bench baseline; the pool is bypassed too).
    pub cache_enabled: bool,
    /// Submit with `try_push`: a full queue yields a typed
    /// `RejectedFull` outcome instead of blocking the client.
    pub reject_when_full: bool,
    /// Keep each solution panel in its [`RequestOutcome`] (tests only —
    /// a real session would stream them out).
    pub keep_solutions: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            queue_depth: 32,
            engines: 3,
            workers: 3,
            clients: 4,
            cache_bytes: 256 << 20,
            cache_enabled: true,
            reject_when_full: false,
            keep_solutions: false,
        }
    }
}

/// A matrix resolved once per (source, seed): canonical CSR +
/// fingerprint.
struct LoadedMatrix {
    csr: Csr,
    fp: MatrixFingerprint,
}

/// One admitted request in flight.
struct Envelope {
    spec: SolveRequest,
    enqueued: Instant,
    done: mpsc::Sender<RequestOutcome>,
}

/// Shared state of one service session.
struct ServiceState {
    cfg: ServeConfig,
    queue: AdmissionQueue<Envelope>,
    cache: Mutex<PlanCache>,
    pool: EnginePool,
    matrices: Mutex<HashMap<(String, u64), Arc<LoadedMatrix>>>,
}

/// What a successful solve hands back to the outcome builder.
struct Solved {
    x: Vec<f64>,
    iterations: usize,
    converged: bool,
    matvecs: usize,
    cache_hit: bool,
    engine_reused: bool,
    /// The first attempt lost its engine to an injected rank death and
    /// this answer came from a retry on a rebuilt engine.
    recovered: bool,
    key_label: String,
}

/// `MatVecOp`/`MultiVecOp` adapter over a checked-out engine, counting
/// distributed applications for the throughput metrics.
struct EngineOp<'a> {
    engine: &'a mut PmvcEngine,
    matvecs: usize,
}

impl MatVecOp for EngineOp<'_> {
    fn order(&self) -> usize {
        self.engine.order()
    }

    fn apply_into(&mut self, x: &[f64], y: &mut [f64]) -> crate::Result<()> {
        self.engine.apply_into(x, y)?;
        self.matvecs += 1;
        Ok(())
    }

    fn apply_dots_into(
        &mut self,
        x: &[f64],
        y: &mut [f64],
        pairs: &[(&[f64], &[f64])],
        dots: &mut [f64],
    ) -> crate::Result<()> {
        self.engine.apply_dots_into(x, y, pairs, dots)?;
        self.matvecs += 1;
        Ok(())
    }
}

impl MultiVecOp for EngineOp<'_> {
    fn apply_multi_into(&mut self, x: &[f64], y: &mut [f64], k: usize) -> crate::Result<()> {
        self.engine.apply_multi_into(x, y, k)?;
        self.matvecs += k;
        Ok(())
    }
}

/// The deterministic RHS panel of a request: column `j` is
/// `A·x_true_j` with `x_true_j[i]` a small seeded affine pattern — the
/// sweep driver's recipe, so served solves are comparable to `run`.
pub fn rhs_panel(a: &Csr, k: usize, seed: u64) -> Vec<f64> {
    let n = a.n_rows;
    let mut b = Vec::with_capacity(n * k);
    for j in 0..k {
        let x_true: Vec<f64> = (0..n)
            .map(|i| {
                let mix = (i as u64).wrapping_mul(j as u64 + 1).wrapping_add(seed) % 13;
                (mix as f64) * 0.25 - 1.5
            })
            .collect();
        b.extend(a.matvec(&x_true));
    }
    b
}

/// Run the request's solver against a checked-out engine. `nrhs > 1`
/// dispatches to the batched solvers (one shared panel apply per
/// iteration); `nrhs == 1` goes through the classic registry.
fn run_solver(a: &Csr, spec: &SolveRequest, engine: &mut PmvcEngine) -> crate::Result<Solved> {
    let b = rhs_panel(a, spec.nrhs, spec.seed);
    let mut op = EngineOp { engine, matvecs: 0 };
    if spec.nrhs > 1 {
        let report = match spec.solver {
            SolverKind::Cg => BlockCg::new()
                .tol(spec.tol)
                .max_iters(spec.max_iters)
                .record_history(false)
                .solve_multi(&mut op, &b, spec.nrhs)?,
            SolverKind::Jacobi => BatchedJacobi::from_matrix(a)?
                .tol(spec.tol)
                .max_iters(spec.max_iters)
                .record_history(false)
                .solve_multi(&mut op, &b, spec.nrhs)?,
            other => anyhow::bail!(
                "nrhs {} needs a batched solver (cg|jacobi), got {other}",
                spec.nrhs
            ),
        };
        Ok(Solved {
            x: report.x,
            iterations: report.max_iterations(),
            converged: report.all_converged(),
            matvecs: op.matvecs,
            cache_hit: false,
            engine_reused: false,
            recovered: false,
            key_label: String::new(),
        })
    } else {
        let mut solver = make_solver_with(spec.solver, a, spec.s_step)?;
        solver.options_mut().tol = spec.tol;
        solver.options_mut().max_iters = spec.max_iters;
        solver.options_mut().record_history = false;
        let report = solver.solve(&mut op, &b)?;
        Ok(Solved {
            x: report.x,
            iterations: report.iterations,
            converged: report.converged,
            matvecs: op.matvecs,
            cache_hit: false,
            engine_reused: false,
            recovered: false,
            key_label: String::new(),
        })
    }
}

/// Resolve (and memoise) the request's matrix: load/generate once per
/// (source, seed), fingerprint once.
fn load_cached_matrix(
    state: &ServiceState,
    matrix: &str,
    seed: u64,
) -> crate::Result<Arc<LoadedMatrix>> {
    let mut matrices = state.matrices.lock().unwrap();
    if let Some(m) = matrices.get(&(matrix.to_string(), seed)) {
        return Ok(Arc::clone(m));
    }
    let csr = load_matrix(matrix, seed)?;
    let fp = fingerprint_csr(&csr);
    let m = Arc::new(LoadedMatrix { csr, fp });
    matrices.insert((matrix.to_string(), seed), Arc::clone(&m));
    Ok(m)
}

/// Build decomposition + frozen plan for `spec` over matrix `a`.
fn build_plan_pair(
    a: &Csr,
    spec: &SolveRequest,
) -> crate::Result<(Arc<TwoLevelDecomposition>, Arc<CommPlan>)> {
    let dcfg =
        DecomposeConfig::with_kinds(spec.partitioner, spec.intra)?.with_format(spec.format);
    let d = Arc::new(decompose(a, spec.combo, spec.nodes, spec.cores, &dcfg)?);
    let plan = Arc::new(CommPlan::build(&d)?);
    Ok((d, plan))
}

/// The injected fault of a request, when it carries one (both fields
/// are validated together at admission).
fn fault_plan_for(spec: &SolveRequest) -> Option<FaultPlan> {
    match (spec.fault_node, spec.fault_apply) {
        (Some(node), Some(at)) => Some(FaultPlan::new().kill(node, at)),
        _ => None,
    }
}

/// Serve one admitted request: matrix → plan cache → engine pool →
/// batched solve. Every error is caught and reported, never panicked.
/// A request carrying an injected fault that kills its engine mid-solve
/// is retried once on a rebuilt engine ([`Solved::recovered`]) instead
/// of dropped.
fn solve_one(state: &ServiceState, spec: &SolveRequest) -> crate::Result<Solved> {
    let m = load_cached_matrix(state, &spec.matrix, spec.seed)?;
    let key = PlanKey {
        fingerprint: m.fp,
        combo: spec.combo,
        inter: spec.partitioner,
        intra: spec.intra,
        format: spec.format,
        f: spec.nodes,
        c: spec.cores,
    };
    if state.cfg.cache_enabled {
        let (d, plan, hit) = {
            let mut cache = state.cache.lock().unwrap();
            cache.get_or_build(&key, || build_plan_pair(&m.csr, spec))?
        };
        let (mut engine, reused) = state
            .pool
            .checkout(&key, || PmvcEngine::with_plan(Arc::clone(&d), Arc::clone(&plan)))?;
        if let Some(fault) = fault_plan_for(spec) {
            if let Err(e) = engine.set_fault_plan(fault) {
                // The plan never armed; the engine is untouched.
                state.pool.checkin(key.clone(), engine);
                return Err(e);
            }
        }
        match run_solver(&m.csr, spec, &mut engine) {
            Ok(s) => {
                // Disarm any un-fired fault before the engine goes back
                // warm, so a later request cannot inherit the kill.
                if spec.fault_node.is_some() {
                    let _ = engine.set_fault_plan(FaultPlan::default());
                }
                state.pool.checkin(key.clone(), engine);
                Ok(Solved { cache_hit: hit, engine_reused: reused, key_label: key.label(), ..s })
            }
            Err(_) if spec.fault_node.is_some() => {
                // The injected kill took the engine down mid-solve:
                // discard it broken, rebuild from the cached plan, and
                // retry from scratch — the retry is bitwise the
                // fault-free solve.
                state.pool.discard(engine);
                let mut engine = PmvcEngine::with_plan(Arc::clone(&d), Arc::clone(&plan))?;
                let s = run_solver(&m.csr, spec, &mut engine)?;
                state.pool.checkin(key.clone(), engine);
                Ok(Solved {
                    recovered: true,
                    cache_hit: hit,
                    engine_reused: reused,
                    key_label: key.label(),
                    ..s
                })
            }
            Err(e) => {
                // The engine goes back warm even when the solve failed —
                // without an injected fault the engine itself is still
                // healthy (solver errors are math/shape errors, not
                // worker deaths).
                state.pool.checkin(key.clone(), engine);
                Err(e)
            }
        }
    } else {
        // Baseline posture: everything rebuilt per request.
        let (d, plan) = build_plan_pair(&m.csr, spec)?;
        let mut engine = PmvcEngine::with_plan(Arc::clone(&d), Arc::clone(&plan))?;
        if let Some(fault) = fault_plan_for(spec) {
            engine.set_fault_plan(fault)?;
        }
        match run_solver(&m.csr, spec, &mut engine) {
            Ok(s) => Ok(Solved { key_label: key.label(), ..s }),
            Err(_) if spec.fault_node.is_some() => {
                drop(engine);
                let mut engine = PmvcEngine::with_plan(d, plan)?;
                let s = run_solver(&m.csr, spec, &mut engine)?;
                Ok(Solved { recovered: true, key_label: key.label(), ..s })
            }
            Err(e) => Err(e),
        }
    }
}

/// Worker side of one envelope: solve, stamp timings, send the outcome.
fn handle_request(state: &ServiceState, env: Envelope) {
    let picked_up = Instant::now();
    let queue_wait_s = picked_up.saturating_duration_since(env.enqueued).as_secs_f64();
    let result = solve_one(state, &env.spec);
    let latency_s = env.enqueued.elapsed().as_secs_f64();
    let outcome = match result {
        Ok(s) => RequestOutcome {
            id: env.spec.id,
            matrix: env.spec.matrix.clone(),
            status: if s.recovered { RequestStatus::Recovered } else { RequestStatus::Completed },
            cache_hit: s.cache_hit,
            engine_reused: s.engine_reused,
            queue_wait_s,
            latency_s,
            iterations: s.iterations,
            converged: s.converged,
            matvecs: s.matvecs,
            key_label: s.key_label,
            x: if state.cfg.keep_solutions { Some(s.x) } else { None },
        },
        Err(e) => RequestOutcome {
            id: env.spec.id,
            matrix: env.spec.matrix.clone(),
            status: RequestStatus::Failed(format!("{e:#}")),
            cache_hit: false,
            engine_reused: false,
            queue_wait_s,
            latency_s,
            iterations: 0,
            converged: false,
            matvecs: 0,
            key_label: String::new(),
            x: None,
        },
    };
    // A dead receiver means the client went away; nothing to do.
    let _ = env.done.send(outcome);
}

/// A rejection outcome (never queued, zero wait).
fn rejected(spec_id: usize, matrix: String, status: RequestStatus) -> RequestOutcome {
    RequestOutcome {
        id: spec_id,
        matrix,
        status,
        cache_hit: false,
        engine_reused: false,
        queue_wait_s: 0.0,
        latency_s: 0.0,
        iterations: 0,
        converged: false,
        matvecs: 0,
        key_label: String::new(),
        x: None,
    }
}

/// Client side: pull the next request off the shared feed, validate,
/// submit, wait for its outcome (closed loop), forward it.
fn client_loop(
    state: &ServiceState,
    feed: &Mutex<std::vec::IntoIter<SolveRequest>>,
    out: &mpsc::Sender<RequestOutcome>,
) {
    loop {
        let spec = {
            let mut it = feed.lock().unwrap();
            it.next()
        };
        let Some(spec) = spec else { return };
        if let Err(reason) = spec.validate() {
            let id = spec.id;
            let _ =
                out.send(rejected(id, spec.matrix, RequestStatus::RejectedInvalid(reason)));
            continue;
        }
        let id = spec.id;
        let matrix = spec.matrix.clone();
        let (done_tx, done_rx) = mpsc::channel();
        let env = Envelope { spec, enqueued: Instant::now(), done: done_tx };
        let pushed = if state.cfg.reject_when_full {
            state.queue.try_push(env)
        } else {
            state.queue.push(env)
        };
        match pushed {
            Ok(()) => {
                if let Ok(outcome) = done_rx.recv() {
                    let _ = out.send(outcome);
                }
            }
            Err(AdmitError::QueueFull { .. }) => {
                let _ = out.send(rejected(id, matrix, RequestStatus::RejectedFull));
            }
            Err(_) => return, // closed: session shutting down
        }
    }
}

/// Fold the session into a [`ServiceReport`].
fn build_report(state: &ServiceState, outcomes: Vec<RequestOutcome>, wall_s: f64) -> ServiceReport {
    let mut completed = 0;
    let mut recovered = 0;
    let mut failed = 0;
    let mut rejected_full = 0;
    let mut rejected_invalid = 0;
    let mut matvecs_total = 0usize;
    let mut waits: Vec<f64> = Vec::new();
    let mut latencies: Vec<f64> = Vec::new();
    for o in &outcomes {
        match &o.status {
            RequestStatus::Completed | RequestStatus::Recovered => {
                if o.status == RequestStatus::Recovered {
                    recovered += 1;
                } else {
                    completed += 1;
                }
                matvecs_total += o.matvecs;
                waits.push(o.queue_wait_s);
                latencies.push(o.latency_s);
            }
            RequestStatus::Failed(_) => failed += 1,
            RequestStatus::RejectedFull => rejected_full += 1,
            RequestStatus::RejectedInvalid(_) => rejected_invalid += 1,
        }
    }
    waits.sort_by(f64::total_cmp);
    latencies.sort_by(f64::total_cmp);
    let cache = state.cache.lock().unwrap();
    let mut per_key: Vec<KeyReport> = cache
        .per_key()
        .iter()
        .map(|(key, s)| KeyReport {
            key: key.clone(),
            hits: s.hits,
            misses: s.misses,
            evictions: s.evictions,
        })
        .collect();
    per_key.sort_by(|a, b| (b.hits + b.misses).cmp(&(a.hits + a.misses)).then(a.key.cmp(&b.key)));
    let pool = state.pool.stats();
    ServiceReport {
        completed,
        recovered,
        failed,
        rejected_full,
        rejected_invalid,
        cache_hits: cache.hits(),
        cache_misses: cache.misses(),
        cache_evictions: cache.evictions(),
        cache_bytes: cache.total_bytes(),
        engines_created: pool.created,
        engines_reused: pool.reused,
        engines_evicted: pool.evicted,
        engines_discarded: pool.discarded,
        engine_peak: pool.peak_live,
        queue_wait_p50_ms: 1e3 * percentile(&waits, 50.0),
        queue_wait_p95_ms: 1e3 * percentile(&waits, 95.0),
        latency_p50_ms: 1e3 * percentile(&latencies, 50.0),
        latency_p95_ms: 1e3 * percentile(&latencies, 95.0),
        wall_s,
        solves_per_sec: if wall_s > 0.0 { (completed + recovered) as f64 / wall_s } else { 0.0 },
        matvecs_per_sec: if wall_s > 0.0 { matvecs_total as f64 / wall_s } else { 0.0 },
        per_key,
        outcomes,
    }
}

/// Serve `requests` through one session and report.
///
/// Spawns [`ServeConfig::clients`] submitters and
/// [`ServeConfig::workers`] solvers, runs the whole list to a terminal
/// state (completed, failed, or rejected — nothing dropped, nothing
/// wedged), then joins every thread and aggregates the
/// [`ServiceReport`].
pub fn run_service(requests: Vec<SolveRequest>, cfg: &ServeConfig) -> crate::Result<ServiceReport> {
    anyhow::ensure!(cfg.workers >= 1, "need at least one worker thread");
    anyhow::ensure!(cfg.clients >= 1, "need at least one client thread");
    let state = Arc::new(ServiceState {
        cfg: cfg.clone(),
        queue: AdmissionQueue::new(cfg.queue_depth),
        cache: Mutex::new(PlanCache::new(cfg.cache_bytes)),
        pool: EnginePool::new(cfg.engines),
        matrices: Mutex::new(HashMap::new()),
    });
    let t0 = Instant::now();
    let mut workers = Vec::with_capacity(cfg.workers);
    for _ in 0..cfg.workers {
        let st = Arc::clone(&state);
        workers.push(std::thread::spawn(move || {
            while let Some(env) = st.queue.pop() {
                handle_request(&st, env);
            }
        }));
    }
    let feed = Arc::new(Mutex::new(requests.into_iter()));
    let (out_tx, out_rx) = mpsc::channel::<RequestOutcome>();
    let mut clients = Vec::with_capacity(cfg.clients);
    for _ in 0..cfg.clients {
        let st = Arc::clone(&state);
        let feed = Arc::clone(&feed);
        let tx = out_tx.clone();
        clients.push(std::thread::spawn(move || client_loop(&st, &feed, &tx)));
    }
    drop(out_tx);
    // Ends when every client dropped its sender (feed exhausted).
    let outcomes: Vec<RequestOutcome> = out_rx.iter().collect();
    for c in clients {
        let _ = c.join();
    }
    state.queue.close();
    for w in workers {
        let _ = w.join();
    }
    let wall_s = t0.elapsed().as_secs_f64();
    Ok(build_report(&state, outcomes, wall_s))
}

/// The reference path: the same request solved without queue, cache or
/// pool — a fresh decomposition, plan and engine, torn down after. The
/// integration tests pin every served solution against this at 1e-9.
pub fn one_shot_solution(spec: &SolveRequest) -> crate::Result<(Vec<f64>, bool)> {
    let a = load_matrix(&spec.matrix, spec.seed)?;
    let (d, plan) = build_plan_pair(&a, spec)?;
    let mut engine = PmvcEngine::with_plan(d, plan)?;
    let s = run_solver(&a, spec, &mut engine)?;
    Ok((s.x, s.converged))
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::trace::RequestDefaults;

    fn small_defaults() -> RequestDefaults {
        RequestDefaults { max_iters: 30, tol: 1e-10, ..Default::default() }
    }

    #[test]
    fn rhs_panel_matches_the_sweep_recipe() {
        let a = crate::sparse::gen::generate_spd(50, 3, 240, 1).to_csr();
        let b = rhs_panel(&a, 2, 0);
        assert_eq!(b.len(), 100);
        // Column 0 with seed 0: x_true[i] = ((i % 13) as f64)*0.25 - 1.5.
        let x0: Vec<f64> = (0..50).map(|i| ((i % 13) as f64) * 0.25 - 1.5).collect();
        assert_eq!(&b[..50], a.matvec(&x0).as_slice());
    }

    #[test]
    fn single_request_session_completes_and_accounts() {
        let d = small_defaults();
        let reqs =
            vec![SolveRequest::new(0, "spd".into(), &d), SolveRequest::new(1, "spd".into(), &d)];
        let cfg = ServeConfig {
            workers: 2,
            clients: 2,
            keep_solutions: true,
            ..ServeConfig::default()
        };
        let report = run_service(reqs.clone(), &cfg).unwrap();
        assert_eq!(report.completed, 2);
        assert_eq!(report.accounted(), 2);
        assert_eq!(report.cache_misses, 1, "second request hits the plan cache");
        assert_eq!(report.cache_hits, 1);
        // Served solutions match the one-shot reference bitwise (same
        // deterministic kernel, same plan).
        let (x_ref, converged) = one_shot_solution(&reqs[0]).unwrap();
        assert!(converged);
        for o in &report.outcomes {
            assert!(o.is_completed());
            assert_eq!(o.x.as_deref().unwrap(), x_ref.as_slice());
        }
    }

    #[test]
    fn pipelined_requests_are_served_and_match_the_one_shot_reference() {
        let d = RequestDefaults::default();
        let mut piped = SolveRequest::new(0, "spd".into(), &d);
        piped.solver = SolverKind::PipelinedCg;
        let mut sstep = SolveRequest::new(1, "spd".into(), &d);
        sstep.solver = SolverKind::SStepCg;
        sstep.s_step = 2;
        let cfg = ServeConfig { keep_solutions: true, ..ServeConfig::default() };
        let report = run_service(vec![piped.clone(), sstep.clone()], &cfg).unwrap();
        assert_eq!(report.completed, 2);
        assert_eq!(report.accounted(), 2);
        for o in &report.outcomes {
            assert!(o.converged, "request {} did not converge", o.id);
            let spec = if o.id == 0 { &piped } else { &sstep };
            let (x_ref, converged) = one_shot_solution(spec).unwrap();
            assert!(converged);
            assert_eq!(o.x.as_deref().unwrap(), x_ref.as_slice());
        }
    }

    #[test]
    fn cache_disabled_rebuilds_per_request() {
        let d = small_defaults();
        let reqs: Vec<SolveRequest> =
            (0..3).map(|i| SolveRequest::new(i, "spd".into(), &d)).collect();
        let cfg = ServeConfig {
            cache_enabled: false,
            workers: 2,
            clients: 2,
            ..ServeConfig::default()
        };
        let report = run_service(reqs, &cfg).unwrap();
        assert_eq!(report.completed, 3);
        assert_eq!(report.cache_hits, 0);
        assert_eq!(report.cache_misses, 0, "cache bypassed entirely");
        assert_eq!(report.engines_created, 0, "pool bypassed entirely");
        assert!(report.hit_rate() == 0.0);
    }

    #[test]
    fn failed_requests_are_reported_not_wedged() {
        let d = small_defaults();
        // Valid at admission (a .mtx path) but missing on disk.
        let reqs = vec![
            SolveRequest::new(0, "definitely/missing/file.mtx".into(), &d),
            SolveRequest::new(1, "spd".into(), &d),
        ];
        let report = run_service(reqs, &ServeConfig::default()).unwrap();
        assert_eq!(report.completed, 1);
        assert_eq!(report.failed, 1);
        assert_eq!(report.accounted(), 2);
        let failed =
            report.outcomes.iter().find(|o| !o.is_completed()).expect("one failed outcome");
        match &failed.status {
            RequestStatus::Failed(msg) => assert!(msg.contains("mtx") || msg.contains("file")),
            other => panic!("expected Failed, got {other:?}"),
        }
    }

    #[test]
    fn fault_injected_request_recovers_on_a_rebuilt_engine() {
        let d = small_defaults();
        let mut chaos = SolveRequest::new(0, "spd".into(), &d);
        chaos.fault_node = Some(1);
        chaos.fault_apply = Some(2);
        assert!(chaos.validate().is_ok());
        let reqs = vec![chaos.clone(), SolveRequest::new(1, "spd".into(), &d)];
        let cfg = ServeConfig { keep_solutions: true, ..ServeConfig::default() };
        let report = run_service(reqs, &cfg).unwrap();
        assert_eq!(report.recovered, 1, "the chaos request must be retried, not dropped");
        assert_eq!(report.completed, 1);
        assert_eq!(report.accounted(), 2);
        assert_eq!(report.engines_discarded, 1, "the broken engine leaves through discard");
        // The retried answer is bitwise the fault-free reference: the
        // retry restarts from scratch on a rebuilt engine.
        let (x_ref, converged) = one_shot_solution(&chaos).unwrap();
        assert!(converged);
        for o in &report.outcomes {
            assert!(o.is_served(), "{:?}", o.status);
            assert_eq!(o.x.as_deref().unwrap(), x_ref.as_slice());
        }
        let rec = report
            .outcomes
            .iter()
            .find(|o| o.status == RequestStatus::Recovered)
            .expect("one recovered outcome");
        assert_eq!(rec.id, 0);
        assert!(rec.converged);
    }

    #[test]
    fn fault_injected_request_recovers_without_the_cache_too() {
        let d = small_defaults();
        let mut chaos = SolveRequest::new(0, "spd".into(), &d);
        chaos.fault_node = Some(0);
        chaos.fault_apply = Some(1);
        let cfg = ServeConfig {
            cache_enabled: false,
            keep_solutions: true,
            ..ServeConfig::default()
        };
        let report = run_service(vec![chaos.clone()], &cfg).unwrap();
        assert_eq!(report.recovered, 1);
        assert_eq!(report.accounted(), 1);
        let (x_ref, _) = one_shot_solution(&chaos).unwrap();
        assert_eq!(report.outcomes[0].x.as_deref().unwrap(), x_ref.as_slice());
    }

    #[test]
    fn invalid_requests_reject_before_the_queue() {
        let d = small_defaults();
        let mut bad = SolveRequest::new(0, "spd".into(), &d);
        bad.nrhs = 4;
        bad.solver = SolverKind::Sor;
        let reqs = vec![bad, SolveRequest::new(1, "spd".into(), &d)];
        let report = run_service(reqs, &ServeConfig::default()).unwrap();
        assert_eq!(report.completed, 1);
        assert_eq!(report.rejected_invalid, 1);
        let rej = report
            .outcomes
            .iter()
            .find(|o| matches!(o.status, RequestStatus::RejectedInvalid(_)))
            .unwrap();
        assert_eq!(rej.id, 0);
        match &rej.status {
            RequestStatus::RejectedInvalid(reason) => {
                assert!(reason.contains("batched solver"));
            }
            _ => unreachable!(),
        }
    }
}
