//! Kernel-tier integration: the tuned raw-speed kernels against the
//! scalar reference, end to end through the distributed engine — the
//! acceptance gates of the `--kernel` tier. Tuned must agree with
//! scalar to 1e-12 across format × backend × schedule × panel width,
//! the CSR tier (and the default build) must stay bitwise-identical to
//! the pre-tier pipeline, and randomized structures (remainder lanes,
//! empty rows, skewed row lengths) must hold the same bound at the
//! kernel level.

use pmvc::cluster::NetworkPreset;
use pmvc::coordinator::experiment::topology_for;
use pmvc::partition::combined::{decompose, Combination, DecomposeConfig};
use pmvc::pmvc::{make_backend, BackendKind, OverlapMode};
use pmvc::rng::SplitMix64;
use pmvc::sparse::gen::{generate, MatrixSpec};
use pmvc::sparse::kernels::{self, KernelSpec, DEFAULT_L2_BYTES};
use pmvc::sparse::{Coo, FormatKind, FragmentStorage, KernelKind, KernelPolicy};

/// A k-wide panel with distinct, deterministic columns.
fn panel(n: usize, k: usize) -> Vec<f64> {
    (0..n * k).map(|i| ((i % 23) as f64) * 0.17 - 1.5).collect()
}

#[test]
fn tuned_tier_agrees_with_scalar_across_format_backend_schedule_and_k() {
    let a = generate(&MatrixSpec::paper("t2dal").unwrap(), 3).to_csr();
    let topo = topology_for(2, 2);
    let net = NetworkPreset::TenGigabitEthernet.model();
    for kind in FormatKind::all() {
        let scfg = DecomposeConfig::default().with_format(kind);
        let tcfg = DecomposeConfig::default()
            .with_format(kind)
            .with_kernel(KernelPolicy::Tuned, DEFAULT_L2_BYTES);
        let ds = decompose(&a, Combination::NlHl, 2, 2, &scfg).unwrap();
        let dt = decompose(&a, Combination::NlHl, 2, 2, &tcfg).unwrap();
        assert_eq!(ds.kernel_kind(), KernelKind::Scalar, "{kind}");
        assert_eq!(dt.kernel_kind(), KernelKind::Tuned, "{kind}");
        for bkind in BackendKind::all() {
            for overlap in [OverlapMode::Blocking, OverlapMode::Overlapped] {
                let mut bs = make_backend(bkind, ds.clone(), &topo, &net).unwrap();
                let mut bt = make_backend(bkind, dt.clone(), &topo, &net).unwrap();
                bs.set_overlap_mode(overlap).unwrap();
                bt.set_overlap_mode(overlap).unwrap();
                for k in [1usize, 4, 16] {
                    let xp = panel(a.n_cols, k);
                    let mut ys = vec![0.0; a.n_rows * k];
                    let mut yt = vec![0.0; a.n_rows * k];
                    bs.apply_multi_into(&xp, &mut ys, k).unwrap();
                    bt.apply_multi_into(&xp, &mut yt, k).unwrap();
                    for i in 0..ys.len() {
                        assert!(
                            (yt[i] - ys[i]).abs() < 1e-12 * (1.0 + ys[i].abs()),
                            "{kind}/{bkind}/{overlap}/k={k} entry {i}: {} vs {}",
                            yt[i],
                            ys[i]
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn tuned_csr_tier_is_bitwise_the_scalar_reference() {
    // the CSR tuned loops reorder nothing within a row, so the tier
    // switch must be invisible at the bit level — on both schedules
    let a = generate(&MatrixSpec::paper("epb1").unwrap(), 2).to_csr();
    let mut rng = SplitMix64::new(29);
    let x: Vec<f64> = (0..a.n_cols).map(|_| rng.next_f64_range(-3.0, 3.0)).collect();
    let topo = topology_for(2, 4);
    let net = NetworkPreset::TenGigabitEthernet.model();
    let ds = decompose(&a, Combination::NlHl, 2, 4, &DecomposeConfig::default()).unwrap();
    let dt = decompose(
        &a,
        Combination::NlHl,
        2,
        4,
        &DecomposeConfig::default().with_kernel(KernelPolicy::Tuned, DEFAULT_L2_BYTES),
    )
    .unwrap();
    for overlap in [OverlapMode::Blocking, OverlapMode::Overlapped] {
        let mut bs = make_backend(BackendKind::Threads, ds.clone(), &topo, &net).unwrap();
        let mut bt = make_backend(BackendKind::Threads, dt.clone(), &topo, &net).unwrap();
        bs.set_overlap_mode(overlap).unwrap();
        bt.set_overlap_mode(overlap).unwrap();
        let ys = bs.apply(&x).unwrap().y;
        let yt = bt.apply(&x).unwrap().y;
        assert_eq!(ys, yt, "{overlap}: tuned CSR must be bitwise the scalar product");
    }
}

#[test]
fn default_build_is_bitwise_the_explicit_scalar_tier() {
    // the zero-surprise guarantee: an untouched DecomposeConfig and an
    // explicit --kernel scalar produce bit-for-bit the same product,
    // i.e. the tier refactor changed nothing unless asked to
    let a = generate(&MatrixSpec::paper("t2dal").unwrap(), 5).to_csr();
    let mut rng = SplitMix64::new(17);
    let x: Vec<f64> = (0..a.n_cols).map(|_| rng.next_f64_range(-1.0, 1.0)).collect();
    let topo = topology_for(2, 2);
    let net = NetworkPreset::TenGigabitEthernet.model();
    let mut ys = Vec::new();
    for cfg in [
        DecomposeConfig::default(),
        DecomposeConfig::default().with_kernel(KernelPolicy::Scalar, DEFAULT_L2_BYTES),
    ] {
        let d = decompose(&a, Combination::NlHl, 2, 2, &cfg).unwrap();
        assert_eq!(d.kernel_kind(), KernelKind::Scalar);
        let mut backend = make_backend(BackendKind::Threads, d, &topo, &net).unwrap();
        ys.push(backend.apply(&x).unwrap().y);
    }
    assert_eq!(ys[0], ys[1], "default must be the scalar tier, bit for bit");
}

/// Random rectangular sparse structures: skewed row lengths exercise
/// the remainder lanes of the 4-wide kernels, empty rows the prefetch
/// edges, and rectangular shapes the row/column bound handling.
fn random_csr(rng: &mut SplitMix64) -> pmvc::sparse::Csr {
    let n_rows = rng.next_range(1, 120);
    let n_cols = rng.next_range(1, 120);
    let mut coo = Coo::new(n_rows, n_cols);
    for i in 0..n_rows {
        // between 0 and 9 entries per row, heavily skewed
        let len = rng.next_below(10).saturating_sub(rng.next_below(4)).min(n_cols);
        for _ in 0..len {
            coo.push(i as u32, rng.next_below(n_cols) as u32, rng.next_f64_range(-2.0, 2.0));
        }
    }
    coo.sum_duplicates().to_csr()
}

#[test]
fn property_tuned_matches_scalar_on_random_structures() {
    let mut rng = SplitMix64::new(0x9E37_79B9);
    for trial in 0..24 {
        let a = random_csr(&mut rng);
        let spec = KernelSpec::resolve(KernelPolicy::Tuned, &a, DEFAULT_L2_BYTES);
        for kind in FormatKind::concrete() {
            let storage = match FragmentStorage::build(&a, kind) {
                Ok(s) => s,
                Err(_) => continue, // DIA budget overflow on scattered trials
            };
            for k in [1usize, 4, 16] {
                let x = panel(a.n_cols, k);
                let mut ys = vec![0.0; a.n_rows * k];
                let mut yt = vec![0.0; a.n_rows * k];
                if k == 1 {
                    storage.mv(&a, &x, &mut ys);
                    kernels::mv(&storage, &a, &spec, &x, &mut yt);
                } else {
                    storage.mv_multi(&a, &x, &mut ys, k);
                    kernels::mv_multi(&storage, &a, &spec, &x, &mut yt, k);
                }
                for i in 0..ys.len() {
                    assert!(
                        (yt[i] - ys[i]).abs() < 1e-12 * (1.0 + ys[i].abs()),
                        "trial {trial} {kind} k={k} ({}x{}) entry {i}: {} vs {}",
                        a.n_rows,
                        a.n_cols,
                        yt[i],
                        ys[i]
                    );
                }
            }
        }
    }
}
