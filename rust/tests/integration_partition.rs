//! Integration: decomposition pipeline across the full Table 4.2 suite —
//! every combination covers every nonzero, respects balance, and the
//! hypergraph intra level beats NEZGT intra on communication volume.

use pmvc::partition::combined::{decompose, Combination, DecomposeConfig, IntraMethod};
use pmvc::partition::hypergraph::Hypergraph;
use pmvc::partition::metrics::CommVolumes;
use pmvc::partition::multilevel::Multilevel;
use pmvc::partition::{baseline, Axis, Nezgt};
use pmvc::sparse::gen::{generate, MatrixSpec};

#[test]
fn full_suite_decompositions_are_exact_covers() {
    // the heavier matrices take a while in debug; use the four smaller
    for name in ["bcsstm09", "thermal", "t2dal", "epb1"] {
        let a = generate(&MatrixSpec::paper(name).unwrap(), 1).to_csr();
        for combo in Combination::all() {
            let d = decompose(&a, combo, 4, 8, &DecomposeConfig::default());
            d.validate(&a).unwrap_or_else(|e| panic!("{name} {combo}: {e}"));
            assert!(d.lb_nodes() < 1.6, "{name} {combo}: LB_nodes {}", d.lb_nodes());
        }
    }
}

#[test]
fn nezgt_load_balance_beats_contiguous_across_suite() {
    for name in ["thermal", "epb1", "zhao1"] {
        let a = generate(&MatrixSpec::paper(name).unwrap(), 1).to_csr();
        let w = a.row_counts();
        for f in [2usize, 8, 32] {
            let nez = Nezgt::ligne().partition_weights(&w, f);
            let contig = baseline::contiguous_blocks(w.len(), f);
            assert!(
                nez.imbalance(&w) <= contig.imbalance(&w) + 1e-9,
                "{name} f={f}: NEZGT {} vs contiguous {}",
                nez.imbalance(&w),
                contig.imbalance(&w)
            );
        }
    }
}

#[test]
fn hypergraph_intra_cuts_less_than_nezgt_intra() {
    // the paper's reason for using the hypergraph at the communication-
    // sensitive level: lower (λ-1) cut than the balance-only heuristic
    let a = generate(&MatrixSpec::paper("t2dal").unwrap(), 1).to_csr();
    let hg = Hypergraph::from_matrix(&a, Axis::Row);
    let ml = Multilevel::default().partition(&hg, 8);
    let nez = Nezgt::ligne().partition(&a, 8);
    let cut_ml = hg.lambda_minus_one_cut(&ml);
    let cut_nez = hg.lambda_minus_one_cut(&nez);
    assert!(
        cut_ml < cut_nez,
        "multilevel cut {cut_ml} should beat NEZGT cut {cut_nez} on a band matrix"
    );
}

#[test]
fn comm_volume_row_vs_col_inter_node() {
    // NL inter: Y footprints partition N (gather = N); NC inter: X
    // footprints partition N (scatter X = N) — the structural duality the
    // paper's ch. 3 §4.2.3 describes.
    let a = generate(&MatrixSpec::paper("epb1").unwrap(), 1).to_csr();
    let dl = decompose(&a, Combination::NlHl, 8, 8, &DecomposeConfig::default());
    let dc = decompose(&a, Combination::NcHc, 8, 8, &DecomposeConfig::default());
    let vl = CommVolumes::of(&dl);
    let vc = CommVolumes::of(&dc);
    assert_eq!(vl.total_gather(), a.n_rows);
    assert_eq!(vc.x_per_node.iter().sum::<usize>(), a.n_cols);
    assert!(vc.total_gather() > vl.total_gather());
    assert!(vl.x_per_node.iter().sum::<usize>() > vc.x_per_node.iter().sum::<usize>());
}

#[test]
fn intra_method_ablation_hypergraph_vs_nezgt() {
    let a = generate(&MatrixSpec::paper("t2dal").unwrap(), 5).to_csr();
    let hyp = decompose(&a, Combination::NlHl, 4, 8, &DecomposeConfig::default());
    let nez = decompose(
        &a,
        Combination::NlHl,
        4,
        8,
        &DecomposeConfig { intra_method: IntraMethod::Nezgt, ..Default::default() },
    );
    hyp.validate(&a).unwrap();
    nez.validate(&a).unwrap();
    // NEZGT intra balances at least as well (it optimizes only balance)
    assert!(nez.lb_cores() <= hyp.lb_cores() + 0.35);
}

#[test]
fn scaling_f_reduces_fragment_sizes() {
    let a = generate(&MatrixSpec::paper("thermal").unwrap(), 1).to_csr();
    let mut prev_max = usize::MAX;
    for f in [2usize, 4, 8, 16] {
        let d = decompose(&a, Combination::NlHl, f, 8, &DecomposeConfig::default());
        let max_core = d.core_loads().into_iter().max().unwrap() as usize;
        assert!(max_core <= prev_max, "f={f}: {max_core} > {prev_max}");
        prev_max = max_core;
    }
}
