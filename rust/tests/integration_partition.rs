//! Integration: decomposition pipeline across the full Table 4.2 suite —
//! every combination covers every nonzero, respects balance, and the
//! hypergraph intra level beats NEZGT intra on communication volume.

use pmvc::partition::combined::{decompose, Combination, DecomposeConfig};
use pmvc::partition::hypergraph::Hypergraph;
use pmvc::partition::metrics::CommVolumes;
use pmvc::partition::multilevel::Multilevel;
use pmvc::partition::{baseline, make_partitioner, Axis, Nezgt, Partitioner, PartitionerKind};
use pmvc::sparse::gen::{generate, MatrixSpec};
use pmvc::sparse::{Coo, Csr};

#[test]
fn full_suite_decompositions_are_exact_covers() {
    // the heavier matrices take a while in debug; use the four smaller
    for name in ["bcsstm09", "thermal", "t2dal", "epb1"] {
        let a = generate(&MatrixSpec::paper(name).unwrap(), 1).to_csr();
        for combo in Combination::all() {
            let d = decompose(&a, combo, 4, 8, &DecomposeConfig::default()).unwrap();
            d.validate(&a).unwrap_or_else(|e| panic!("{name} {combo}: {e}"));
            assert!(d.lb_nodes() < 1.6, "{name} {combo}: LB_nodes {}", d.lb_nodes());
        }
    }
}

#[test]
fn nezgt_load_balance_beats_contiguous_across_suite() {
    for name in ["thermal", "epb1", "zhao1"] {
        let a = generate(&MatrixSpec::paper(name).unwrap(), 1).to_csr();
        let w = a.row_counts();
        for f in [2usize, 8, 32] {
            let nez = Nezgt::ligne().partition_weights(&w, f);
            let contig = baseline::contiguous_blocks(w.len(), f);
            assert!(
                nez.imbalance(&w) <= contig.imbalance(&w) + 1e-9,
                "{name} f={f}: NEZGT {} vs contiguous {}",
                nez.imbalance(&w),
                contig.imbalance(&w)
            );
        }
    }
}

#[test]
fn hypergraph_intra_cuts_less_than_nezgt_intra() {
    // the paper's reason for using the hypergraph at the communication-
    // sensitive level: lower (λ-1) cut than the balance-only heuristic
    let a = generate(&MatrixSpec::paper("t2dal").unwrap(), 1).to_csr();
    let hg = Hypergraph::from_matrix(&a, Axis::Row);
    let ml = Multilevel::default().partition(&hg, 8);
    let nez = Nezgt::ligne().partition(&a, 8);
    let cut_ml = hg.lambda_minus_one_cut(&ml);
    let cut_nez = hg.lambda_minus_one_cut(&nez);
    assert!(
        cut_ml < cut_nez,
        "multilevel cut {cut_ml} should beat NEZGT cut {cut_nez} on a band matrix"
    );
}

#[test]
fn comm_volume_row_vs_col_inter_node() {
    // NL inter: Y footprints partition N (gather = N); NC inter: X
    // footprints partition N (scatter X = N) — the structural duality the
    // paper's ch. 3 §4.2.3 describes.
    let a = generate(&MatrixSpec::paper("epb1").unwrap(), 1).to_csr();
    let dl = decompose(&a, Combination::NlHl, 8, 8, &DecomposeConfig::default()).unwrap();
    let dc = decompose(&a, Combination::NcHc, 8, 8, &DecomposeConfig::default()).unwrap();
    let vl = CommVolumes::of(&dl);
    let vc = CommVolumes::of(&dc);
    assert_eq!(vl.total_gather(), a.n_rows);
    assert_eq!(vc.x_per_node.iter().sum::<usize>(), a.n_cols);
    assert!(vc.total_gather() > vl.total_gather());
    assert!(vl.x_per_node.iter().sum::<usize>() > vc.x_per_node.iter().sum::<usize>());
}

#[test]
fn intra_method_ablation_hypergraph_vs_nezgt() {
    let a = generate(&MatrixSpec::paper("t2dal").unwrap(), 5).to_csr();
    let hyp = decompose(&a, Combination::NlHl, 4, 8, &DecomposeConfig::default()).unwrap();
    let nez = decompose(&a, Combination::NlHl, 4, 8, &DecomposeConfig::nezgt_both()).unwrap();
    hyp.validate(&a).unwrap();
    nez.validate(&a).unwrap();
    // NEZGT intra balances at least as well (it optimizes only balance)
    assert!(nez.lb_cores() <= hyp.lb_cores() + 0.35);
}

/// A block-diagonal matrix (plus a thin inter-block coupling) whose
/// rows are *striped*: row `r` belongs to block `r % blocks`, so the
/// block structure is invisible to index order but fully visible to
/// connectivity. Contiguous index splits shred every block across every
/// part; a connectivity-driven partitioner can keep blocks whole.
fn striped_block_diagonal_plus_coupling(blocks: usize, size: usize) -> Csr {
    let n = blocks * size;
    let row_of = |b: usize, i: usize| (i * blocks + b) as u32;
    let mut m = Coo::new(n, n);
    for b in 0..blocks {
        for i in 0..size {
            for j in 0..size {
                m.push(row_of(b, i), row_of(b, j), 1.0);
            }
        }
    }
    // sparse coupling: one symmetric link between consecutive blocks
    for b in 1..blocks {
        m.push(row_of(b - 1, 0), row_of(b, 0), 0.5);
        m.push(row_of(b, 0), row_of(b - 1, 0), 0.5);
    }
    m.to_csr()
}

#[test]
fn multilevel_beats_contiguous_blocks_on_lambda1_cut() {
    // 8 striped blocks of 8 into k=4 (2 whole blocks per part is both
    // balanced and nearly cut-free): contiguous quarters intersect every
    // block, giving λ ≈ 4 on every column net.
    let a = striped_block_diagonal_plus_coupling(8, 8);
    let hg = Hypergraph::from_matrix(&a, Axis::Row);
    let ml = make_partitioner(PartitionerKind::Hypergraph).unwrap();
    let contig = make_partitioner(PartitionerKind::Contig).unwrap();
    let p_ml = ml.partition(&a, Axis::Row, 4).unwrap();
    let p_ct = contig.partition(&a, Axis::Row, 4).unwrap();
    let cut_ml = hg.lambda_minus_one_cut(&p_ml);
    let cut_ct = hg.lambda_minus_one_cut(&p_ct);
    assert!(
        cut_ml < cut_ct,
        "multilevel cut {cut_ml} must beat contiguous blocks cut {cut_ct} on block structure"
    );
}

#[test]
fn every_registered_partitioner_produces_exact_covers() {
    // the registry end-to-end: any 1-D strategy at either level still
    // yields a valid decomposition (all nonzeros exactly once)
    let a = generate(&MatrixSpec::paper("bcsstm09").unwrap(), 1).to_csr();
    for inter in PartitionerKind::one_dimensional() {
        let cfg = DecomposeConfig::with_kinds(inter, PartitionerKind::Hypergraph).unwrap();
        let d = decompose(&a, Combination::NlHl, 4, 4, &cfg).unwrap();
        d.validate(&a).unwrap_or_else(|e| panic!("inter={inter}: {e}"));
        assert_eq!(d.quality.inter_partitioner, inter.name());
        assert!(d.quality.comm_bytes > 0, "inter={inter}");
    }
}

#[test]
fn nezgt_vs_hypergraph_inter_trade_balance_for_cut() {
    // the paper's central trade-off, now selectable: NEZGT optimizes
    // LB_nodes, the hypergraph optimizes the (λ−1) cut — each should
    // win its own metric on a structured matrix
    let a = generate(&MatrixSpec::paper("t2dal").unwrap(), 1).to_csr();
    let nez = decompose(&a, Combination::NlHl, 8, 2, &DecomposeConfig::default()).unwrap();
    let cfg =
        DecomposeConfig::with_kinds(PartitionerKind::Hypergraph, PartitionerKind::Hypergraph)
            .unwrap();
    let hyp = decompose(&a, Combination::NlHl, 8, 2, &cfg).unwrap();
    assert!(
        nez.quality.lb_nodes <= hyp.quality.lb_nodes + 1e-9,
        "NEZGT LB_nodes {} vs hypergraph {}",
        nez.quality.lb_nodes,
        hyp.quality.lb_nodes
    );
    assert!(
        hyp.quality.cut < nez.quality.cut,
        "hypergraph cut {} vs NEZGT {}",
        hyp.quality.cut,
        nez.quality.cut
    );
    // and the cut difference prices through to bytes on the wire
    assert!(
        hyp.quality.comm_bytes < nez.quality.comm_bytes,
        "hypergraph comm {} B vs NEZGT {} B",
        hyp.quality.comm_bytes,
        nez.quality.comm_bytes
    );
}

#[test]
fn scaling_f_reduces_fragment_sizes() {
    let a = generate(&MatrixSpec::paper("thermal").unwrap(), 1).to_csr();
    let mut prev_max = usize::MAX;
    for f in [2usize, 4, 8, 16] {
        let d = decompose(&a, Combination::NlHl, f, 8, &DecomposeConfig::default()).unwrap();
        let max_core = d.core_loads().into_iter().max().unwrap() as usize;
        assert!(max_core <= prev_max, "f={f}: {max_core} > {prev_max}");
        prev_max = max_core;
    }
}
