//! The PR's survival matrix: every solver × backend × kill-schedule
//! cell must survive a mid-solve rank death — the recovery driver
//! replans over the survivors, warm-restarts from the checkpoint, still
//! converges, agrees with the fault-free run at 1e-9, and records the
//! restart in the report.

use pmvc::coordinator::{solve_with_recovery, RecoverySpec};
use pmvc::partition::combined::{Combination, DecomposeConfig};
use pmvc::pmvc::{BackendKind, FaultPlan};
use pmvc::rng::SplitMix64;
use pmvc::solver::SolverKind;
use pmvc::sparse::gen;
use pmvc::sparse::Csr;

fn spd_system(n: usize, seed: u64, k: usize) -> (Csr, Vec<f64>) {
    let a = gen::generate_spd(n, 3, n * 5, seed).to_csr();
    let mut rng = SplitMix64::new(seed ^ 0xF00D);
    let b = (0..n * k).map(|_| rng.next_f64_range(-1.0, 1.0)).collect();
    (a, b)
}

fn spec<'a>(
    a: &'a Csr,
    solver: SolverKind,
    nrhs: usize,
    backend: BackendKind,
    fault: FaultPlan,
) -> RecoverySpec<'a> {
    RecoverySpec {
        a,
        combo: Combination::NlHl,
        cfg: DecomposeConfig::default(),
        backend,
        solver,
        s_step: 4,
        nrhs,
        f: 3,
        c: 2,
        // 1e-12 leaves ~3 decades of margin under the 1e-9 answer gate:
        // both runs land within tol·||b|| of the true solution, so their
        // difference is bounded far below 1e-9 (λ_min >= 1 by
        // construction of generate_spd).
        tol: 1e-12,
        max_iters: 8000,
        fault,
    }
}

#[test]
fn survival_matrix_every_solver_backend_and_kill_schedule() {
    // (label, solver kind, panel width): "block-cg" is CG over a panel.
    let solvers = [
        ("cg", SolverKind::Cg, 1usize),
        ("jacobi", SolverKind::Jacobi, 1),
        ("block-cg", SolverKind::Cg, 3),
    ];
    let backends = [BackendKind::Threads, BackendKind::Sim, BackendKind::Mpi];
    for (label, solver, nrhs) in solvers {
        let (a, b) = spd_system(200, 11, nrhs);
        for backend in backends {
            // the fault-free reference for this cell
            let clean = solve_with_recovery(
                &spec(&a, solver, nrhs, backend, FaultPlan::new()),
                &b,
            )
            .unwrap();
            assert!(clean.report.converged, "{label}/{backend}: clean run must converge");
            assert_eq!(clean.report.restarts, 0, "{label}/{backend}");
            let applies = clean.report.applies;
            assert!(
                applies >= 2,
                "{label}/{backend}: {applies} applies leave no room to kill mid-solve"
            );
            // kill node 1 at the first, a middle, and the last apply
            for kill_at in [1, (applies / 2).max(1), applies] {
                let out = solve_with_recovery(
                    &spec(&a, solver, nrhs, backend, FaultPlan::new().kill(1, kill_at)),
                    &b,
                )
                .unwrap();
                let tag = format!("{label}/{backend}/kill@{kill_at}");
                assert!(out.report.converged, "{tag}: must still converge");
                assert!(out.report.restarts >= 1, "{tag}: the restart must be recorded");
                assert!(out.report.warm_started, "{tag}: resume must be a warm start");
                assert_eq!(out.f_final, 2, "{tag}: one node died");
                assert_eq!(out.events.len(), out.report.restarts, "{tag}");
                assert_eq!(out.events[0].f_before, 3, "{tag}");
                assert_eq!(out.events[0].f_after, 2, "{tag}");
                for (i, (x, x_ref)) in out.report.x.iter().zip(&clean.report.x).enumerate() {
                    assert!(
                        (x - x_ref).abs() < 1e-9,
                        "{tag} row {i}: answer drifted {:.3e} past the 1e-9 gate",
                        (x - x_ref).abs()
                    );
                }
            }
        }
    }
}

#[test]
fn fault_schedule_execution_is_deterministic() {
    // Same seed + same schedule ⇒ identical recovery trajectory and a
    // bitwise-identical answer: every candidate partition, the reseed
    // salt, and the rebased schedule are pure functions of the spec.
    let (a, b) = spd_system(180, 3, 1);
    let plan = FaultPlan::new().kill(1, 5);
    let s1 = solve_with_recovery(
        &spec(&a, SolverKind::Cg, 1, BackendKind::Threads, plan.clone()),
        &b,
    )
    .unwrap();
    let s2 =
        solve_with_recovery(&spec(&a, SolverKind::Cg, 1, BackendKind::Threads, plan), &b).unwrap();
    assert_eq!(s1.report.x, s2.report.x, "same seed + schedule must be bitwise identical");
    assert_eq!(s1.report.iterations, s2.report.iterations);
    assert_eq!(s1.report.applies, s2.report.applies);
    assert_eq!(s1.report.restarts, s2.report.restarts);
    assert_eq!(s1.f_final, s2.f_final);
    assert_eq!(s1.events.len(), s2.events.len());
    for (e1, e2) in s1.events.iter().zip(&s2.events) {
        // replan_s is wall-clock and excluded; everything else is exact
        assert_eq!(e1.at_iteration, e2.at_iteration);
        assert_eq!(
            (e1.f_before, e1.f_after, e1.repartitioned),
            (e2.f_before, e2.f_after, e2.repartitioned)
        );
    }
}

#[test]
fn two_scheduled_deaths_are_survived_in_order() {
    // f = 4 shrinks to 2 across two restarts; the events arrive in
    // schedule order and the answer still matches the clean run.
    let (a, b) = spd_system(200, 7, 1);
    let mut clean_spec = spec(&a, SolverKind::Cg, 1, BackendKind::Threads, FaultPlan::new());
    clean_spec.f = 4;
    let clean = solve_with_recovery(&clean_spec, &b).unwrap();
    assert!(clean.report.converged);

    let mut chaos_spec = spec(
        &a,
        SolverKind::Cg,
        1,
        BackendKind::Threads,
        FaultPlan::new().kill(3, 2).kill(1, 9),
    );
    chaos_spec.f = 4;
    let out = solve_with_recovery(&chaos_spec, &b).unwrap();
    assert!(out.report.converged);
    assert_eq!(out.report.restarts, 2);
    assert_eq!(out.f_final, 2);
    assert_eq!(out.events[0].f_before, 4);
    assert_eq!(out.events[0].f_after, 3);
    assert_eq!(out.events[1].f_before, 3);
    assert_eq!(out.events[1].f_after, 2);
    for (i, (x, x_ref)) in out.report.x.iter().zip(&clean.report.x).enumerate() {
        assert!((x - x_ref).abs() < 1e-9, "row {i}");
    }
}
