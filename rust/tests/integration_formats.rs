//! Format-generic PMVC integration: lossless CSR ↔ format round-trips
//! over the Table 4.2 suite plus edge cases, and the
//! solver × backend × format agreement matrix at 1e-12 against serial
//! CSR — the acceptance gates of the per-fragment storage-selection
//! refactor.

use pmvc::cluster::NetworkPreset;
use pmvc::coordinator::experiment::topology_for;
use pmvc::partition::combined::{decompose, Combination, DecomposeConfig};
use pmvc::pmvc::{make_backend, BackendKind, ExecBackend, OverlapMode};
use pmvc::rng::SplitMix64;
use pmvc::solver::{Cg, DistributedOp, IterativeSolver, MatVecOp};
use pmvc::sparse::formats_ext::{Bsr, CsrDu, Dia, Jad};
use pmvc::sparse::gen::{generate, generate_spd, MatrixSpec};
use pmvc::sparse::{Coo, Csr, EllStore, FormatKind, FragmentStorage};

/// The full Table 4.2 synthetic suite.
fn table42() -> Vec<(String, Csr)> {
    ["bcsstm09", "thermal", "t2dal", "ex19", "epb1", "af23560", "spmsrtls", "zhao1"]
        .iter()
        .map(|n| (n.to_string(), generate(&MatrixSpec::paper(n).unwrap(), 1).to_csr()))
        .collect()
}

/// Degenerate structures every conversion must survive.
fn edge_cases() -> Vec<(String, Csr)> {
    let empty = Coo::new(6, 6).to_csr();
    let mut holes = Coo::new(6, 6);
    holes.push(0, 1, 1.5);
    holes.push(2, 0, -2.0);
    holes.push(2, 5, 3.0);
    holes.push(5, 5, 0.25); // rows 1, 3, 4 stay empty
    let mut dense_row = Coo::new(5, 5);
    for j in 0..5u32 {
        dense_row.push(0, j, (j + 1) as f64);
    }
    vec![
        ("empty".to_string(), empty),
        ("empty-rows".to_string(), holes.to_csr()),
        ("single-dense-row".to_string(), dense_row.to_csr()),
    ]
}

#[test]
fn formats_roundtrip_table42_suite_and_edge_cases() {
    let mut cases = table42();
    cases.extend(edge_cases());
    for (name, a) in &cases {
        assert_eq!(&EllStore::from_csr(a).to_csr(), a, "{name}: ELL");
        assert_eq!(&Jad::from_csr(a).to_csr(), a, "{name}: JAD");
        assert_eq!(&CsrDu::from_csr(a).to_csr(), a, "{name}: CSR-DU");
        for b in [1usize, 2, 4] {
            assert_eq!(&Bsr::from_csr(a, b).to_csr(), a, "{name}: BSR b={b}");
        }
        // DIA only where the diagonal budget admits the structure (the
        // scattered matrices legitimately overflow — with a typed
        // reason, not a silent None)
        match Dia::from_csr(a, 4096) {
            Ok(dia) => assert_eq!(&dia.to_csr(), a, "{name}: DIA"),
            Err(e) => assert!(e.to_string().contains("diagonals"), "{name}: {e}"),
        }
    }
}

#[test]
fn every_format_backend_schedule_agrees_with_serial_at_1e12() {
    let a = generate(&MatrixSpec::paper("t2dal").unwrap(), 3).to_csr();
    let mut rng = SplitMix64::new(41);
    let x: Vec<f64> = (0..a.n_cols).map(|_| rng.next_f64_range(-1.0, 1.0)).collect();
    let y_ref = a.matvec(&x);
    let topo = topology_for(2, 2);
    let net = NetworkPreset::TenGigabitEthernet.model();
    for kind in FormatKind::all() {
        let cfg = DecomposeConfig::default().with_format(kind);
        let d = decompose(&a, Combination::NlHl, 2, 2, &cfg).unwrap();
        for bkind in BackendKind::all() {
            for overlap in [OverlapMode::Blocking, OverlapMode::Overlapped] {
                let mut backend = make_backend(bkind, d.clone(), &topo, &net).unwrap();
                backend.set_overlap_mode(overlap).unwrap();
                let r = backend.apply(&x).unwrap();
                for i in 0..a.n_rows {
                    assert!(
                        (r.y[i] - y_ref[i]).abs() < 1e-12 * (1.0 + y_ref[i].abs()),
                        "{kind}/{bkind}/{overlap} row {i}: {} vs {}",
                        r.y[i],
                        y_ref[i]
                    );
                }
            }
        }
    }
}

#[test]
fn cg_solves_through_every_format_on_the_distributed_engine() {
    // banded SPD so DIA admits the structure too
    let a = generate_spd(240, 5, 1600, 7).to_csr();
    let x_true: Vec<f64> = (0..240).map(|i| ((i % 9) as f64) * 0.3 - 1.2).collect();
    let b = a.matvec(&x_true);
    for kind in FormatKind::all() {
        let cfg = DecomposeConfig::default().with_format(kind);
        let d = decompose(&a, Combination::NlHl, 2, 2, &cfg).unwrap();
        let mut op = DistributedOp::new(d).unwrap();
        let r = Cg::new().tol(1e-12).max_iters(800).solve(&mut op, &b).unwrap();
        assert!(r.converged, "{kind}: CG must converge");
        for i in 0..240 {
            assert!(
                (r.x[i] - x_true[i]).abs() < 1e-7 * (1.0 + x_true[i].abs()),
                "{kind} row {i}"
            );
        }
    }
}

#[test]
fn default_pipeline_is_bitwise_the_csr_format() {
    // the zero-overhead guarantee: an explicitly requested --format csr
    // and the untouched default produce bit-for-bit the same product
    // through the engine, on both schedules
    let a = generate(&MatrixSpec::paper("epb1").unwrap(), 2).to_csr();
    let mut rng = SplitMix64::new(29);
    let x: Vec<f64> = (0..a.n_cols).map(|_| rng.next_f64_range(-3.0, 3.0)).collect();
    let topo = topology_for(2, 4);
    let net = NetworkPreset::TenGigabitEthernet.model();
    for overlap in [OverlapMode::Blocking, OverlapMode::Overlapped] {
        let mut ys = Vec::new();
        for cfg in [
            DecomposeConfig::default(),
            DecomposeConfig::default().with_format(FormatKind::Csr),
        ] {
            let d = decompose(&a, Combination::NlHl, 2, 4, &cfg).unwrap();
            let mut backend = make_backend(BackendKind::Threads, d, &topo, &net).unwrap();
            backend.set_overlap_mode(overlap).unwrap();
            ys.push(backend.apply(&x).unwrap().y);
        }
        assert_eq!(ys[0], ys[1], "{overlap}: default must be the CSR format, bit for bit");
    }
}

#[test]
fn stored_bytes_track_the_format_choice() {
    let a = generate(&MatrixSpec::paper("t2dal").unwrap(), 1).to_csr();
    let bytes_for = |kind: FormatKind| {
        let cfg = DecomposeConfig::default().with_format(kind);
        decompose(&a, Combination::NlHl, 2, 2, &cfg).unwrap().stored_bytes()
    };
    let csr = bytes_for(FormatKind::Csr);
    // the delta-compressed index stream undercuts CSR on a banded matrix
    assert!(bytes_for(FormatKind::CsrDu) < csr);
    // BSR's zero-filled 4×4 blocks pay for register blocking with bytes
    assert!(bytes_for(FormatKind::Bsr) > csr);
}

#[test]
fn auto_is_a_per_fragment_choice_with_auditable_rejections() {
    use pmvc::sparse::auto_select;
    let a = generate(&MatrixSpec::paper("zhao1").unwrap(), 1).to_csr();
    let (kind, notes) = auto_select(&a);
    assert_ne!(kind, FormatKind::Dia, "zhao1 scatters over too many diagonals");
    assert!(notes.iter().any(|n| n.contains("dia rejected")), "{notes:?}");
    // and the storage auto-built for a fragment still computes correctly
    let storage = FragmentStorage::build(&a, FormatKind::Auto).unwrap();
    let mut rng = SplitMix64::new(3);
    let x: Vec<f64> = (0..a.n_cols).map(|_| rng.next_f64_range(-1.0, 1.0)).collect();
    let y_ref = a.matvec(&x);
    let mut y = vec![0.0; a.n_rows];
    storage.mv(&a, &x, &mut y);
    for i in 0..a.n_rows {
        assert!((y[i] - y_ref[i]).abs() < 1e-12 * (1.0 + y_ref[i].abs()), "row {i}");
    }
}

#[test]
fn serial_format_operators_drive_all_solvers() {
    // every solver × every serial format operator: the satellite that
    // makes the format catalogue first-class for the solver layer too
    use pmvc::solver::{make_solver, SolverKind};
    let a = generate_spd(160, 4, 1000, 13).to_csr();
    let x_true: Vec<f64> = (0..160).map(|i| ((i % 5) as f64) * 0.4).collect();
    let b = a.matvec(&x_true);
    let mut du = CsrDu::from_csr(&a);
    let mut jad = Jad::from_csr(&a);
    let mut ell = EllStore::from_csr(&a);
    let mut bsr = Bsr::from_csr(&a, 4);
    let mut dia = Dia::from_csr(&a, 4096).unwrap();
    let ops: [(&str, &mut dyn MatVecOp); 5] = [
        ("csrdu", &mut du),
        ("jad", &mut jad),
        ("ell", &mut ell),
        ("bsr", &mut bsr),
        ("dia", &mut dia),
    ];
    for (label, op) in ops {
        for skind in SolverKind::all() {
            let mut solver = make_solver(skind, &a).unwrap();
            solver.options_mut().tol = 1e-10;
            solver.options_mut().max_iters = if skind == SolverKind::Lanczos { 30 } else { 4000 };
            solver.options_mut().record_history = false;
            let r = solver.solve(op, &b).unwrap();
            assert!(r.iterations > 0, "{label}/{skind}");
            if skind == SolverKind::Cg {
                assert!(r.converged, "{label}/cg must converge on the SPD system");
            }
        }
    }
}
