//! Integration: the pipelined Krylov solvers across the full execution
//! matrix — {cg, pipelined-cg, sstep-cg} × {threads, sim, mpi} ×
//! {blocking, overlapped} all land on the same answer at 1e-9, and a
//! rank death mid-pipeline (fused dot operands in flight) is survived
//! through the checkpointed recovery driver.

use pmvc::cluster::{ClusterTopology, NetworkPreset};
use pmvc::coordinator::{solve_with_recovery, RecoverySpec};
use pmvc::partition::combined::{decompose, Combination, DecomposeConfig};
use pmvc::pmvc::{make_backend, BackendKind, FaultPlan, OverlapMode};
use pmvc::rng::SplitMix64;
use pmvc::solver::{make_solver_with, Cg, DistributedOp, IterativeSolver, SolverKind};
use pmvc::sparse::{gen, Csr};

fn spd_system(n: usize, seed: u64) -> (Csr, Vec<f64>) {
    let a = gen::generate_spd(n, 3, n * 5, seed).to_csr();
    let mut rng = SplitMix64::new(seed ^ 0x5EED);
    let b = (0..n).map(|_| rng.next_f64_range(-1.0, 1.0)).collect();
    (a, b)
}

#[test]
fn solver_matrix_agrees_across_backends_and_schedules() {
    let (a, b) = spd_system(220, 7);
    let reference = Cg::new().tol(1e-10).max_iters(1200).solve(&mut a.clone(), &b).unwrap();
    assert!(reference.converged, "serial CG reference must converge");
    let topo = ClusterTopology::paravance(3);
    let net = NetworkPreset::TenGigabitEthernet.model();
    for kind in [SolverKind::Cg, SolverKind::PipelinedCg, SolverKind::SStepCg] {
        for backend_kind in BackendKind::all() {
            for mode in [OverlapMode::Blocking, OverlapMode::Overlapped] {
                let d =
                    decompose(&a, Combination::NlHl, 3, 2, &DecomposeConfig::default()).unwrap();
                let mut backend = make_backend(backend_kind, d, &topo, &net).unwrap();
                backend.set_overlap_mode(mode).unwrap();
                let mut op = DistributedOp::with_backend(backend);
                let mut solver = make_solver_with(kind, &a, 3).unwrap();
                solver.options_mut().tol = 1e-10;
                solver.options_mut().max_iters = 1200;
                solver.options_mut().record_history = false;
                let r = solver.solve(&mut op, &b).unwrap();
                assert!(r.converged, "{kind} over {backend_kind}/{mode} did not converge");
                for i in 0..a.n_rows {
                    assert!(
                        (r.x[i] - reference.x[i]).abs() < 1e-9 * (1.0 + reference.x[i].abs()),
                        "{kind} over {backend_kind}/{mode}: x[{i}] drifted ({} vs {})",
                        r.x[i],
                        reference.x[i]
                    );
                }
                let phases = r.phases.expect("distributed solves report phases");
                if kind != SolverKind::Cg {
                    assert!(
                        phases.t_reduce > 0.0,
                        "{kind} over {backend_kind}/{mode}: fused rounds must price reductions"
                    );
                }
            }
        }
    }
}

fn recovery_spec<'a>(a: &'a Csr, kind: SolverKind, fault: FaultPlan) -> RecoverySpec<'a> {
    RecoverySpec {
        a,
        combo: Combination::NlHl,
        cfg: DecomposeConfig::default(),
        backend: BackendKind::Mpi,
        solver: kind,
        s_step: 2,
        nrhs: 1,
        f: 3,
        c: 2,
        tol: 1e-10,
        max_iters: 2000,
        fault,
    }
}

#[test]
fn pipelined_solve_survives_rank_death_mid_pipeline() {
    let (a, b) = spd_system(160, 11);
    for kind in [SolverKind::PipelinedCg, SolverKind::SStepCg] {
        let reference =
            solve_with_recovery(&recovery_spec(&a, kind, FaultPlan::new()), &b).unwrap();
        assert!(reference.report.converged, "{kind} fault-free reference");
        assert_eq!(reference.report.restarts, 0);
        // the 5th distributed apply is mid-loop for both solvers: the
        // pipelined round (and the s-step block) has fused dot operands
        // in flight when the rank dies
        let out =
            solve_with_recovery(&recovery_spec(&a, kind, FaultPlan::new().kill(1, 5)), &b).unwrap();
        assert!(out.report.converged, "{kind} did not reconverge after the kill");
        assert_eq!(out.report.restarts, 1, "{kind}");
        assert!(out.report.warm_started, "{kind} must resume from the checkpoint");
        assert_eq!(out.f_final, 2, "{kind}");
        for i in 0..a.n_rows {
            assert!(
                (out.report.x[i] - reference.report.x[i]).abs() < 1e-8,
                "{kind}: recovered x[{i}] drifted"
            );
        }
    }
}
