//! Batched multi-vector integration: the SpMM kernels, the packed
//! k-slice transport, and the block solvers, gated end to end. The
//! contract under test is the PR 6 tentpole — every panel column is
//! bitwise the single-vector product of that column, on every format,
//! backend and schedule, and Block-CG reproduces k independent CG
//! solves column for column.

use pmvc::cluster::NetworkPreset;
use pmvc::coordinator::experiment::topology_for;
use pmvc::partition::combined::{decompose, Combination, DecomposeConfig};
use pmvc::pmvc::{make_backend, BackendKind, ExecBackend, OverlapMode};
use pmvc::rng::SplitMix64;
use pmvc::solver::{BlockCg, Cg, ColumnReport, DistributedOp, IterativeSolver, MultiSolveReport};
use pmvc::sparse::gen::{generate, generate_spd, MatrixSpec};
use pmvc::sparse::{Coo, FormatKind, FragmentStorage};

/// Column-major panel with `k` distinct pseudo-random columns.
fn panel(n: usize, k: usize, rng: &mut SplitMix64) -> Vec<f64> {
    (0..n * k).map(|_| rng.next_f64_range(-2.0, 2.0)).collect()
}

#[test]
fn mv_multi_is_bitwise_k_single_mv_on_every_format() {
    let mut rng = SplitMix64::new(61);
    for name in ["t2dal", "epb1"] {
        let a = generate(&MatrixSpec::paper(name).unwrap(), 1).to_csr();
        for kind in FormatKind::concrete() {
            let storage = match FragmentStorage::build(&a, kind) {
                Ok(s) => s,
                Err(_) => continue, // format legitimately rejects the structure
            };
            for k in [1usize, 3, 8] {
                let x = panel(a.n_cols, k, &mut rng);
                let mut y = vec![0.0; a.n_rows * k];
                storage.mv_multi(&a, &x, &mut y, k);
                let mut y1 = vec![0.0; a.n_rows];
                for j in 0..k {
                    storage.mv(&a, &x[j * a.n_cols..(j + 1) * a.n_cols], &mut y1);
                    assert_eq!(
                        &y[j * a.n_rows..(j + 1) * a.n_rows],
                        &y1[..],
                        "{name}/{}/k={k}: column {j} must be bitwise the single mv",
                        kind.name()
                    );
                }
            }
        }
    }
}

#[test]
fn panel_product_agrees_across_format_backend_schedule() {
    let a = generate(&MatrixSpec::paper("t2dal").unwrap(), 3).to_csr();
    let mut rng = SplitMix64::new(17);
    let topo = topology_for(2, 2);
    let net = NetworkPreset::TenGigabitEthernet.model();
    for k in [1usize, 4] {
        let x = panel(a.n_cols, k, &mut rng);
        // serial reference, column by column
        let y_ref: Vec<Vec<f64>> =
            (0..k).map(|j| a.matvec(&x[j * a.n_cols..(j + 1) * a.n_cols])).collect();
        for kind in FormatKind::all() {
            let cfg = DecomposeConfig::default().with_format(kind);
            let d = decompose(&a, Combination::NlHl, 2, 2, &cfg).unwrap();
            for bkind in BackendKind::all() {
                for overlap in [OverlapMode::Blocking, OverlapMode::Overlapped] {
                    let mut backend = make_backend(bkind, d.clone(), &topo, &net).unwrap();
                    backend.set_overlap_mode(overlap).unwrap();
                    let mut y = vec![0.0; a.n_rows * k];
                    backend.apply_multi_into(&x, &mut y, k).unwrap();
                    for j in 0..k {
                        for i in 0..a.n_rows {
                            let (got, want) = (y[j * a.n_rows + i], y_ref[j][i]);
                            assert!(
                                (got - want).abs() < 1e-12 * (1.0 + want.abs()),
                                "{kind}/{bkind}/{overlap}/k={k} col {j} row {i}: {got} vs {want}"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn block_cg_reproduces_per_column_cg_through_the_engine() {
    // banded SPD so every format admits the structure; both the block
    // solve and the k reference solves run on the distributed engine
    let a = generate_spd(240, 5, 1600, 7).to_csr();
    let k = 3usize;
    let n = 240usize;
    let mut b = vec![0.0; n * k];
    for j in 0..k {
        let x_true: Vec<f64> = (0..n).map(|i| ((i * (j + 2) % 11) as f64) * 0.4 - 1.0).collect();
        b[j * n..(j + 1) * n].copy_from_slice(&a.matvec(&x_true));
    }

    let d = decompose(&a, Combination::NlHl, 2, 2, &DecomposeConfig::default()).unwrap();
    let mut op = DistributedOp::new(d.clone()).unwrap();
    let r = BlockCg::new().tol(1e-10).max_iters(800).solve_multi(&mut op, &b, k).unwrap();
    assert!(r.all_converged(), "block-cg must converge on the SPD panel");
    assert_eq!(r.panel_applies, r.max_iterations(), "one shared panel apply per iteration");

    for j in 0..k {
        let mut op_j = DistributedOp::new(d.clone()).unwrap();
        let rj = Cg::new()
            .tol(1e-10)
            .max_iters(800)
            .solve(&mut op_j, &b[j * n..(j + 1) * n])
            .unwrap();
        let col = &r.columns[j];
        assert_eq!(rj.iterations, col.iterations, "column {j} trajectory length");
        assert!(
            (rj.residual_norm - col.residual_norm).abs() <= 1e-9 * (1.0 + rj.residual_norm),
            "column {j} residual: block {} vs solo {}",
            col.residual_norm,
            rj.residual_norm
        );
        for i in 0..n {
            assert!(
                (r.column_x(j)[i] - rj.x[i]).abs() < 1e-9 * (1.0 + rj.x[i].abs()),
                "column {j} row {i}"
            );
        }
    }
}

#[test]
fn panel_column_extraction_roundtrips_exactly() {
    // hand-rolled property test (no proptest in the tree): for random
    // shapes and values, packing k columns into a column-major panel and
    // extracting them back — directly, via MultiSolveReport::column_x,
    // and through mv_multi — is exact, bit for bit
    let mut rng = SplitMix64::new(97);
    for trial in 0..25 {
        let n = rng.next_range(1, 120);
        let k = rng.next_range(1, 9);
        let cols: Vec<Vec<f64>> = (0..k)
            .map(|_| (0..n).map(|_| rng.next_f64_range(-1e6, 1e6)).collect())
            .collect();

        // pack, then extract: bitwise round-trip
        let mut x = Vec::with_capacity(n * k);
        for c in &cols {
            x.extend_from_slice(c);
        }
        for (j, c) in cols.iter().enumerate() {
            assert_eq!(&x[j * n..(j + 1) * n], &c[..], "trial {trial}: slice extraction");
        }

        // the report's accessor is the same slicing, bit for bit
        let report = MultiSolveReport {
            solver: "block-cg",
            k,
            x: x.clone(),
            columns: vec![
                ColumnReport {
                    iterations: 0,
                    residual_norm: 0.0,
                    converged: true,
                    history: Vec::new(),
                };
                k
            ],
            wall_time: 0.0,
            panel_applies: 0,
            phases: None,
        };
        for (j, c) in cols.iter().enumerate() {
            assert_eq!(report.column_x(j), &c[..], "trial {trial}: column_x");
        }

        // and the panel kernel sees exactly the column the slice sees:
        // mv_multi over the packed panel == mv over each extracted column
        let mut coo = Coo::new(n, n);
        for i in 0..n as u32 {
            coo.push(i, i, rng.next_f64_range(0.5, 2.0));
            let j = rng.next_below(n) as u32;
            coo.push(i, j, rng.next_f64_range(-1.0, 1.0));
        }
        let a = coo.to_csr();
        let storage = FragmentStorage::build(&a, FormatKind::Csr).unwrap();
        let mut y = vec![0.0; n * k];
        storage.mv_multi(&a, &x, &mut y, k);
        let mut y1 = vec![0.0; n];
        for j in 0..k {
            storage.mv(&a, &x[j * n..(j + 1) * n], &mut y1);
            assert_eq!(&y[j * n..(j + 1) * n], &y1[..], "trial {trial}: kernel column {j}");
        }
    }
}
