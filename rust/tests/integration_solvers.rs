//! Integration: iterative methods driven end-to-end through the
//! distributed PMVC — the workloads the paper's introduction motivates
//! (RSL by CG/Jacobi, eigenvalue/PageRank by power iteration).

use pmvc::partition::combined::{decompose, Combination, DecomposeConfig};
use pmvc::solver::cg::conjugate_gradient;
use pmvc::solver::jacobi::{diagonal, jacobi};
use pmvc::solver::power::power_iteration;
use pmvc::solver::{DistributedOp, MatVecOp};
use pmvc::sparse::gen;

#[test]
fn cg_through_all_four_combinations() {
    let a = gen::generate_spd(200, 4, 1200, 11).to_csr();
    let x_true: Vec<f64> = (0..200).map(|i| ((i % 7) as f64) - 3.0).collect();
    let b = a.matvec(&x_true);
    for combo in Combination::all() {
        let d = decompose(&a, combo, 2, 2, &DecomposeConfig::default());
        let mut op = DistributedOp::new(d);
        let r = conjugate_gradient(&mut op, &b, 1e-10, 600);
        assert!(r.converged, "{combo}: CG residual {}", r.residual_norm);
        for i in 0..200 {
            assert!((r.x[i] - x_true[i]).abs() < 1e-5, "{combo} x[{i}]");
        }
        assert_eq!(op.applications, r.iterations);
        // the matrix is scattered once per apply in this backend; the
        // accumulated phase stats must be populated
        assert!(op.accumulated.t_compute > 0.0);
    }
}

#[test]
fn jacobi_distributed_converges() {
    let a = gen::generate_spd(150, 3, 900, 13).to_csr();
    let diag = diagonal(&a);
    let x_true: Vec<f64> = (0..150).map(|i| (i as f64 * 0.05).sin()).collect();
    let b = a.matvec(&x_true);
    let d = decompose(&a, Combination::NlHl, 2, 2, &DecomposeConfig::default());
    let mut op = DistributedOp::new(d);
    let r = jacobi(&mut op, &diag, &b, 1e-9, 4000);
    assert!(r.converged, "residual {}", r.residual_norm);
    for i in 0..150 {
        assert!((r.x[i] - x_true[i]).abs() < 1e-5);
    }
}

#[test]
fn pagerank_distributed_matches_serial_ranking() {
    let q = gen::generate_link_matrix(300, 6, 21).to_csr();
    let mut serial = q.clone();
    let rs = power_iteration(&mut serial, 0.85, 1e-12, 400);

    let dq = decompose(&q, Combination::NcHc, 2, 2, &DecomposeConfig::default());
    let mut dist = DistributedOp::new(dq);
    let rd = power_iteration(&mut dist, 0.85, 1e-12, 400);

    assert!(rs.converged && rd.converged);
    for i in 0..300 {
        assert!((rs.v[i] - rd.v[i]).abs() < 1e-9, "score {i}");
    }
    // top-10 ranking identical
    let top = |v: &[f64]| {
        let mut idx: Vec<usize> = (0..v.len()).collect();
        idx.sort_by(|&a, &b| v[b].partial_cmp(&v[a]).unwrap());
        idx.truncate(10);
        idx
    };
    assert_eq!(top(&rs.v), top(&rd.v));
}

#[test]
fn distributed_op_reports_per_iteration_cost() {
    let a = gen::generate_spd(100, 3, 600, 17).to_csr();
    let d = decompose(&a, Combination::NlHl, 2, 2, &DecomposeConfig::default());
    let mut op = DistributedOp::new(d);
    let x = vec![1.0; 100];
    for _ in 0..5 {
        op.apply(&x);
    }
    assert_eq!(op.applications, 5);
    assert!(op.mean_iteration_time() > 0.0);
    assert!(op.accumulated.t_total() >= op.mean_iteration_time() * 4.99);
}
