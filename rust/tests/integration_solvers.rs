//! Integration: the solver × backend matrix. Every [`IterativeSolver`]
//! runs through the one trait over serial CSR, the persistent threaded
//! engine and the simulated cluster, converging to the same answer —
//! on both the blocking and the overlapped schedule, which must agree
//! to 1e-12; a corrupted decomposition, a dying backend or a dead MPI
//! rank surfaces as `Err` from `solve` instead of the old silent
//! zero-vector stall (or process abort).

use pmvc::cluster::NetworkPreset;
use pmvc::coordinator::experiment::topology_for;
use pmvc::partition::combined::{decompose, Combination, DecomposeConfig};
use pmvc::pmvc::{make_backend, BackendKind, ExecBackend, MpiOp, OverlapMode, PhaseTimes};
use pmvc::solver::{
    make_solver, Cg, DistributedOp, IterativeSolver, MatVecOp, Power, SolveReport, SolverError,
    SolverKind,
};
use pmvc::sparse::gen;
use pmvc::sparse::Csr;

/// Strictly diagonally dominant SPD system: CG/Jacobi/SOR all converge,
/// and Lanczos sees a clean positive spectrum.
fn spd_system() -> (Csr, Vec<f64>) {
    let a = gen::generate_spd(150, 3, 900, 29).to_csr();
    let x_true: Vec<f64> = (0..150).map(|i| ((i % 7) as f64) * 0.5 - 1.5).collect();
    let b = a.matvec(&x_true);
    (a, b)
}

/// Damped PageRank on a link matrix: the power method's geometric
/// convergence case (|λ2| ≤ damping).
fn link_system() -> Csr {
    gen::generate_link_matrix(200, 6, 17).to_csr()
}

fn configure(solver: &mut dyn IterativeSolver, kind: SolverKind) {
    // Lanczos cost is O(steps²·n) with full reorthogonalization — a
    // fixed small step count is both fast and deterministic
    solver.options_mut().max_iters = if kind == SolverKind::Lanczos { 30 } else { 20_000 };
    solver.options_mut().tol = 1e-12;
}

/// Run `kind` over the serial CSR (backend `None`) or a distributed
/// backend wrapped in [`DistributedOp`], on the requested schedule.
fn solve_over_mode(
    kind: SolverKind,
    backend: Option<BackendKind>,
    mode: OverlapMode,
    a: &Csr,
    b: &[f64],
) -> SolveReport {
    let mut solver = if kind == SolverKind::Power {
        // the damped variant needs the concrete builder
        Box::new(Power::new().damping(0.85)) as Box<dyn IterativeSolver>
    } else {
        make_solver(kind, a).unwrap()
    };
    configure(solver.as_mut(), kind);
    match backend {
        None => solver.solve(&mut a.clone(), b).unwrap(),
        Some(bk) => {
            let (f, c) = (2usize, 2usize);
            let topo = topology_for(f, c);
            let net = NetworkPreset::TenGigabitEthernet.model();
            let d = decompose(a, Combination::NlHl, f, c, &DecomposeConfig::default()).unwrap();
            let be = make_backend(bk, d, &topo, &net).unwrap();
            let mut op = DistributedOp::with_backend(be);
            op.set_overlap_mode(mode).unwrap();
            let report = solver.solve(&mut op, b).unwrap();
            assert_eq!(op.applications, report.applies, "{kind}/{bk}");
            assert!(
                report.phases.is_some(),
                "{kind}/{bk}: a distributed solve must self-report phase times"
            );
            report
        }
    }
}

fn solve_over(
    kind: SolverKind,
    backend: Option<BackendKind>,
    a: &Csr,
    b: &[f64],
) -> SolveReport {
    solve_over_mode(kind, backend, OverlapMode::Blocking, a, b)
}

#[test]
fn every_solver_matches_serial_over_threads_and_sim() {
    let (a_spd, b_spd) = spd_system();
    let a_link = link_system();
    for kind in SolverKind::all() {
        // power gets the geometric-convergence PageRank case; the
        // others solve/diagonalize the SPD system
        let (a, b): (&Csr, &[f64]) = if kind == SolverKind::Power {
            (&a_link, &[])
        } else {
            (&a_spd, &b_spd)
        };
        let serial = solve_over(kind, None, a, b);
        assert!(serial.converged, "{kind} serial did not converge");
        assert_eq!(serial.solver, kind.name());
        for bk in [BackendKind::Threads, BackendKind::Sim] {
            let dist = solve_over(kind, Some(bk), a, b);
            assert!(dist.converged, "{kind}/{bk} did not converge");
            if serial.x.is_empty() {
                // Lanczos answers with Ritz values, not a vector
                let (ls, ld) = (serial.lambda.unwrap(), dist.lambda.unwrap());
                assert!(
                    (ls - ld).abs() < 1e-9 * (1.0 + ls.abs()),
                    "{kind}/{bk}: lambda {ls} vs {ld}"
                );
            } else {
                assert_eq!(serial.x.len(), dist.x.len());
                for i in 0..serial.x.len() {
                    assert!(
                        (serial.x[i] - dist.x[i]).abs() < 1e-9,
                        "{kind}/{bk} x[{i}]: {} vs {}",
                        serial.x[i],
                        dist.x[i]
                    );
                }
            }
        }
    }
}

#[test]
fn blocking_and_overlapped_agree_across_solver_backend_matrix() {
    // the overlap acceptance gate: for every solver × backend cell, the
    // two schedules must produce the same answer to 1e-12 (the threaded
    // engine is in fact bitwise-identical; 1e-12 leaves room for the
    // solvers' own floating-point reductions)
    let (a_spd, b_spd) = spd_system();
    let a_link = link_system();
    for kind in SolverKind::all() {
        let (a, b): (&Csr, &[f64]) = if kind == SolverKind::Power {
            (&a_link, &[])
        } else {
            (&a_spd, &b_spd)
        };
        for bk in [BackendKind::Threads, BackendKind::Sim] {
            let blocking = solve_over_mode(kind, Some(bk), OverlapMode::Blocking, a, b);
            let overlapped = solve_over_mode(kind, Some(bk), OverlapMode::Overlapped, a, b);
            assert!(blocking.converged && overlapped.converged, "{kind}/{bk}");
            assert_eq!(blocking.iterations, overlapped.iterations, "{kind}/{bk}");
            if blocking.x.is_empty() {
                let (lb, lo) = (blocking.lambda.unwrap(), overlapped.lambda.unwrap());
                assert!((lb - lo).abs() <= 1e-12 * (1.0 + lb.abs()), "{kind}/{bk}: {lb} vs {lo}");
            } else {
                for i in 0..blocking.x.len() {
                    assert!(
                        (blocking.x[i] - overlapped.x[i]).abs() <= 1e-12,
                        "{kind}/{bk} x[{i}]: {} vs {}",
                        blocking.x[i],
                        overlapped.x[i]
                    );
                }
            }
            let saved = overlapped.phases.unwrap().t_overlap_saved;
            assert!(saved >= 0.0, "{kind}/{bk}");
        }
    }
    // mpi spawns real rank threads per cell — one representative cell
    // instead of the full matrix
    let blocking = solve_over_mode(SolverKind::Cg, Some(BackendKind::Mpi), OverlapMode::Blocking, &a_spd, &b_spd);
    let overlapped =
        solve_over_mode(SolverKind::Cg, Some(BackendKind::Mpi), OverlapMode::Overlapped, &a_spd, &b_spd);
    assert!(blocking.converged && overlapped.converged);
    for i in 0..blocking.x.len() {
        assert!((blocking.x[i] - overlapped.x[i]).abs() <= 1e-12, "cg/mpi x[{i}]");
    }
}

#[test]
fn trait_objects_sweep_all_solvers() {
    // the coordinator's usage pattern: pick a solver at run time, drive
    // it through options_mut on the trait object
    let (a, b) = spd_system();
    for kind in SolverKind::all() {
        let mut solver = make_solver(kind, &a).unwrap();
        configure(solver.as_mut(), kind);
        assert_eq!(solver.name(), kind.name());
        let r = solver.solve(&mut a.clone(), &b).unwrap();
        assert!(r.iterations > 0, "{kind}");
        assert_eq!(r.solver, kind.name());
    }
}

#[test]
fn corrupted_decomposition_makes_solve_fail() {
    let (a, b) = spd_system();
    let mut d = decompose(&a, Combination::NlHl, 2, 2, &DecomposeConfig::default()).unwrap();
    let frag = d.fragments.iter_mut().find(|fr| !fr.global_rows.is_empty()).unwrap();
    frag.global_rows.pop();
    // the plan validator rejects the corruption eagerly
    assert!(DistributedOp::new(d).is_err());

    // a backend dying mid-solve surfaces as Err from solve (the old
    // infallible MatVecOp degraded to a zero vector and stalled)
    struct FailingBackend {
        n: usize,
        calls: usize,
    }
    impl ExecBackend for FailingBackend {
        fn name(&self) -> &'static str {
            "failing"
        }
        fn order(&self) -> usize {
            self.n
        }
        fn apply_into(&mut self, _x: &[f64], _y: &mut [f64]) -> pmvc::Result<PhaseTimes> {
            self.calls += 1;
            anyhow::bail!("simulated node failure at apply {}", self.calls)
        }
    }
    let mut op = DistributedOp::with_backend(Box::new(FailingBackend { n: a.n_rows, calls: 0 }));
    let err = Cg::new().tol(1e-10).max_iters(100).solve(&mut op, &b).unwrap_err();
    // the failure is typed and checkpointed: no iteration completed, so
    // the carried iterate is the zero cold-start vector
    match &err {
        SolverError::Interrupted { at_iteration, x, .. } => {
            assert_eq!(*at_iteration, 0);
            assert!(x.iter().all(|&v| v == 0.0));
        }
        other => panic!("expected Interrupted, got {other:?}"),
    }
    assert!(err.to_string().contains("simulated node failure"));
}

#[test]
fn dying_mpi_rank_makes_solve_fail_instead_of_aborting() {
    // a rank that dies mid-solve used to hit `.expect("node rank died")`
    // and take the whole process down; now the solve reports Err on
    // both schedules and the caller decides what to do next
    let (a, b) = spd_system();
    for mode in [OverlapMode::Blocking, OverlapMode::Overlapped] {
        let d = decompose(&a, Combination::NlHl, 2, 2, &DecomposeConfig::default()).unwrap();
        let mut op = MpiOp::new(&d).unwrap();
        op.cluster.set_overlap_mode(mode);
        // a first iteration goes through fine
        let mut y = vec![0.0; a.n_rows];
        op.apply_into(&b, &mut y).unwrap();
        // then rank 0 dies; the next solve must surface a typed error
        op.cluster.kill_rank(0);
        let err = Cg::new().tol(1e-10).max_iters(100).solve(&mut op, &b).unwrap_err();
        assert!(matches!(err, SolverError::Interrupted { .. }), "{mode}");
        assert!(err.to_string().contains("rank 0"), "{mode}: {err}");
        op.cluster.shutdown();
    }
}

#[test]
fn residual_history_and_observer_survive_the_distributed_path() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    let (a, b) = spd_system();
    let d = decompose(&a, Combination::NcHc, 2, 2, &DecomposeConfig::default()).unwrap();
    let mut op = DistributedOp::new(d).unwrap();
    let seen = Arc::new(AtomicUsize::new(0));
    let s2 = Arc::clone(&seen);
    let mut solver = Cg::new().tol(1e-10).max_iters(600).observer(move |_, _| {
        s2.fetch_add(1, Ordering::SeqCst);
    });
    let r = solver.solve(&mut op, &b).unwrap();
    assert!(r.converged);
    assert_eq!(r.history.len(), r.iterations);
    assert_eq!(seen.load(Ordering::SeqCst), r.iterations);
    // history is the residual trace: strictly positive, final below tol
    assert!(r.history.iter().all(|&h| h > 0.0));
    assert!(*r.history.last().unwrap() <= 1e-10 * (1.0 + b.iter().map(|x| x * x).sum::<f64>()));
}

#[test]
fn mpi_backend_joins_the_matrix_through_distributed_op() {
    // mpi spawns real rank threads per cell — exercised once here
    // rather than inside the full matrix
    let (a, b) = spd_system();
    let serial = solve_over(SolverKind::Cg, None, &a, &b);
    let dist = solve_over(SolverKind::Cg, Some(BackendKind::Mpi), &a, &b);
    assert!(serial.converged && dist.converged);
    for i in 0..serial.x.len() {
        assert!((serial.x[i] - dist.x[i]).abs() < 1e-9, "x[{i}]");
    }
}
