//! Integration: the XLA/PJRT runtime path — AOT artifacts loaded from
//! `artifacts/`, executed through PJRT, compared against the native
//! Rust kernel and the f64 serial product.
//!
//! Requires `make artifacts` (skipped with a message otherwise).

use pmvc::partition::combined::{decompose, Combination, DecomposeConfig};
use pmvc::rng::SplitMix64;
use pmvc::runtime::Runtime;
use pmvc::sparse::ell::{Bucket, Ell};
use pmvc::sparse::gen::{generate, MatrixSpec};
use pmvc::sparse::Coo;

fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::new() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP runtime tests: {e}");
            None
        }
    }
}

#[test]
fn pfvc_artifact_matches_native_ell() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let a = Coo::from_triplets(
        4,
        6,
        [
            (0, 0, 1.0),
            (0, 3, 2.0),
            (1, 2, 3.0),
            (2, 0, 4.0),
            (2, 1, 5.0),
            (2, 2, 6.0),
            (3, 5, 8.0),
        ],
    )
    .unwrap()
    .to_csr();
    let (ell, _) = Ell::from_csr_auto(&a).unwrap();
    let x: Vec<f32> = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
    let mut y_native = vec![0f32; ell.rows];
    ell.mv_into(&x, &mut y_native).unwrap();
    let y_xla = rt.pfvc_ell(&ell, &x).unwrap();
    assert_eq!(y_xla.len(), 4);
    for i in 0..4 {
        assert!((y_xla[i] - y_native[i]).abs() < 1e-4, "row {i}: {} vs {}", y_xla[i], y_native[i]);
    }
}

#[test]
fn executable_cache_compiles_once_per_bucket() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let a = generate(&MatrixSpec::paper("bcsstm09").unwrap(), 1).to_csr();
    let frag = a.select_rows(&(0..60).collect::<Vec<_>>());
    let x = vec![1f32; a.n_cols];
    rt.pfvc_csr(&frag, &x).unwrap();
    let compiles_after_first = rt.compiles;
    rt.pfvc_csr(&frag, &x).unwrap();
    rt.pfvc_csr(&frag, &x).unwrap();
    assert_eq!(rt.compiles, compiles_after_first, "cache miss on repeat shape");
    assert_eq!(rt.executions, 3);
}

#[test]
fn whole_decomposition_through_xla_matches_serial() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let a = generate(&MatrixSpec::paper("t2dal").unwrap(), 17).to_csr();
    let mut rng = SplitMix64::new(2);
    let x: Vec<f64> = (0..a.n_cols).map(|_| rng.next_f64_range(-1.0, 1.0)).collect();
    let y_ref = a.matvec(&x);
    let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();

    for combo in [Combination::NlHl, Combination::NcHc] {
        let d = decompose(&a, combo, 2, 4, &DecomposeConfig::default()).unwrap();
        let mut y = vec![0f64; a.n_rows];
        for frag in &d.fragments {
            if frag.csr.nnz() == 0 {
                continue;
            }
            let mut xl = vec![0f32; frag.csr.n_cols];
            for (lc, &g) in frag.global_cols.iter().enumerate() {
                xl[lc] = xf[g as usize];
            }
            let yl = rt.pfvc_csr(&frag.csr, &xl).unwrap();
            for (lr, &g) in frag.global_rows.iter().enumerate() {
                y[g as usize] += yl[lr] as f64;
            }
        }
        for i in 0..a.n_rows {
            let rel = (y[i] - y_ref[i]).abs() / (1.0 + y_ref[i].abs());
            assert!(rel < 1e-3, "{combo} row {i}: {} vs {}", y[i], y_ref[i]);
        }
    }
}

#[test]
fn covering_bucket_resolution() {
    let Some(rt) = runtime_or_skip() else { return };
    assert_eq!(rt.covering(60, 7), Some(Bucket { rows: 64, width: 8 }));
    assert_eq!(rt.covering(65, 8), Some(Bucket { rows: 128, width: 8 }));
    assert_eq!(rt.covering(1_000_000, 8), None);
    assert!(rt.buckets().len() >= 40);
}

#[test]
fn missing_artifacts_dir_fails_cleanly() {
    let err = Runtime::with_dir(std::path::PathBuf::from("/nonexistent/pmvc-artifacts"))
        .err()
        .expect("should fail");
    let msg = format!("{err}");
    assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
}

#[test]
fn iterative_method_through_xla_runtime() {
    // the full build-time story: jacobi iterations whose PFVC runs the
    // AOT artifact every sweep (x changes, A stays resident)
    let Some(mut rt) = runtime_or_skip() else { return };
    let n = 200;
    let a = pmvc::sparse::gen::generate_spd(n, 3, 1200, 31).to_csr();
    let x_true: Vec<f32> = (0..n).map(|i| ((i % 7) as f32) * 0.3 - 1.0).collect();
    let xt64: Vec<f64> = x_true.iter().map(|&v| v as f64).collect();
    let b: Vec<f32> = a.matvec(&xt64).iter().map(|&v| v as f32).collect();
    let mut diag = vec![0f32; n];
    for i in 0..n {
        for (c, v) in a.row(i) {
            if c as usize == i {
                diag[i] = v as f32;
            }
        }
    }
    let mut x = vec![0f32; n];
    for _ in 0..400 {
        let ax = rt.pfvc_csr(&a, &x).unwrap();
        for i in 0..n {
            x[i] += (b[i] - ax[i]) / diag[i];
        }
    }
    for i in 0..n {
        assert!((x[i] - x_true[i]).abs() < 1e-2, "x[{i}] = {} vs {}", x[i], x_true[i]);
    }
    // A never re-shipped: one executable, hundreds of executions
    assert!(rt.compiles <= 2);
    assert_eq!(rt.executions, 400);
}

#[test]
fn oversized_fragment_is_rejected() {
    let Some(mut rt) = runtime_or_skip() else { return };
    // a row with width 200 > max K=128
    let mut m = Coo::new(1, 300);
    for j in 0..200u32 {
        m.push(0, j, 1.0);
    }
    let frag = m.to_csr();
    let x = vec![1f32; 300];
    assert!(rt.pfvc_csr(&frag, &x).is_err());
}
