//! End-to-end tests of the solve-as-a-service layer: concurrent clients
//! through the admission queue, plan cache and engine pool, with every
//! served solution pinned against the one-shot reference path.

use pmvc::coordinator::experiment::load_matrix;
use pmvc::service::{
    one_shot_solution, run_service, RequestDefaults, RequestStatus, ServeConfig, SolveRequest,
};
use pmvc::solver::SolverKind;
use pmvc::sparse::fingerprint_csr;
use pmvc::sparse::gen::{generate, MatrixSpec};
use pmvc::sparse::mm::write_matrix_market;
use std::collections::HashMap;

/// Write the synthetic bcsstm09 (seed 1) as a MatrixMarket file and
/// return its path — the ingest source for the mixed-matrix sessions.
fn write_bcsstm09_mtx(tag: &str) -> String {
    let dir = std::env::temp_dir().join("pmvc_service_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("bcsstm09_{tag}_{}.mtx", std::process::id()));
    let m = generate(&MatrixSpec::paper("bcsstm09").unwrap(), 1);
    write_matrix_market(&path, &m).unwrap();
    path.to_str().unwrap().to_string()
}

fn small_defaults() -> RequestDefaults {
    RequestDefaults { tol: 1e-8, max_iters: 60, ..Default::default() }
}

/// Served and reference panels must agree at 1e-9 (bit-identical values
/// also pass, which covers non-finite columns of non-converged solves).
fn assert_panel_agrees(matrix: &str, served: &[f64], reference: &[f64]) {
    assert_eq!(served.len(), reference.len(), "{matrix}: panel shape");
    for (i, (&a, &b)) in served.iter().zip(reference).enumerate() {
        assert!(
            (a - b).abs() <= 1e-9 || a.to_bits() == b.to_bits(),
            "{matrix}: solution diverges from one-shot at {i}: {a} vs {b}"
        );
    }
}

#[test]
fn acceptance_concurrent_mixed_matrix_session() {
    // >= 16 concurrent requests over three distinct matrices, one of
    // them ingested from a MatrixMarket file.
    let mtx = write_bcsstm09_mtx("acceptance");
    let defaults = small_defaults();
    let sources = ["t2dal", "spd", mtx.as_str()];
    let mut requests = Vec::new();
    for id in 0..18 {
        let mut r = SolveRequest::new(id, sources[id % 3].to_string(), &defaults);
        if id % 3 == 1 {
            r.nrhs = 4; // spd requests carry a 4-wide panel through block CG
        }
        requests.push(r);
    }
    let cfg = ServeConfig {
        queue_depth: 8,
        engines: 3,
        workers: 4,
        clients: 6,
        keep_solutions: true,
        ..ServeConfig::default()
    };
    let report = run_service(requests.clone(), &cfg).unwrap();

    // Nothing dropped, nothing wedged, nothing failed.
    assert_eq!(report.accounted(), 18);
    assert_eq!(report.completed, 18);
    assert_eq!(report.failed, 0);
    assert_eq!(report.rejected_full + report.rejected_invalid, 0);

    // Three distinct plan keys -> 3 misses, 15 hits: rate well past 50%.
    assert_eq!(report.cache_misses, 3);
    assert_eq!(report.cache_hits, 15);
    assert!(report.hit_rate() > 0.5, "hit rate {}", report.hit_rate());
    assert!(report.engine_peak <= cfg.engines);
    assert!(report.wall_s > 0.0);
    assert!(report.solves_per_sec > 0.0);

    // Every served solution agrees with the equivalent one-shot run.
    let mut reference: HashMap<(String, usize), Vec<f64>> = HashMap::new();
    for o in &report.outcomes {
        let spec = requests.iter().find(|r| r.id == o.id).unwrap();
        let x_ref = reference
            .entry((spec.matrix.clone(), spec.nrhs))
            .or_insert_with(|| one_shot_solution(spec).unwrap().0);
        assert_panel_agrees(&spec.matrix, o.x.as_deref().unwrap(), x_ref);
    }

    // The JSON report carries the acceptance metrics.
    let json = report.to_json();
    for key in ["\"hit_rate\"", "\"latency_p50_ms\"", "\"latency_p95_ms\"", "\"solves_per_sec\""] {
        assert!(json.contains(key), "missing {key} in {json}");
    }
}

#[test]
fn concurrent_engine_reuse_stays_within_the_pool_bound() {
    // Two distinct cached plans, a pool smaller than the worker count,
    // and a t2dal-heavy prefix that guarantees warm reuse.
    let defaults = small_defaults();
    let mut requests = Vec::new();
    for id in 0..8 {
        requests.push(SolveRequest::new(id, "t2dal".to_string(), &defaults));
    }
    for id in 8..16 {
        let mut r = SolveRequest::new(id, "spd".to_string(), &defaults);
        if id % 2 == 0 {
            r.nrhs = 2;
        }
        requests.push(r);
    }
    let cfg = ServeConfig {
        engines: 2,
        workers: 4,
        clients: 4,
        keep_solutions: true,
        ..ServeConfig::default()
    };
    let report = run_service(requests.clone(), &cfg).unwrap();
    assert_eq!(report.completed, 16);
    assert_eq!(report.failed, 0);
    assert_eq!(report.cache_misses, 2, "one plan build per distinct key");
    assert!(
        report.engine_peak <= 2,
        "pool exceeded its bound: peak {} > 2",
        report.engine_peak
    );
    // The t2dal-only prefix admits at most 2 engine builds, so at least
    // 6 of its 8 requests reuse a warm engine.
    assert!(report.engines_reused >= 6, "only {} warm reuses", report.engines_reused);
    let mut reference: HashMap<(String, usize), Vec<f64>> = HashMap::new();
    for o in &report.outcomes {
        let spec = requests.iter().find(|r| r.id == o.id).unwrap();
        let x_ref = reference
            .entry((spec.matrix.clone(), spec.nrhs))
            .or_insert_with(|| one_shot_solution(spec).unwrap().0);
        assert_panel_agrees(&spec.matrix, o.x.as_deref().unwrap(), x_ref);
    }
}

#[test]
fn tiny_cache_budget_evicts_and_keeps_serving() {
    let defaults = small_defaults();
    let sources = ["bcsstm09", "t2dal", "spd"];
    let requests: Vec<SolveRequest> = (0..12)
        .map(|id| SolveRequest::new(id, sources[id % 3].to_string(), &defaults))
        .collect();
    let cfg = ServeConfig {
        // Far below the footprint of the two large plans together: the
        // session must evict to keep admitting new keys.
        cache_bytes: 400_000,
        workers: 2,
        clients: 2,
        ..ServeConfig::default()
    };
    let report = run_service(requests, &cfg).unwrap();
    assert_eq!(report.completed, 12);
    assert_eq!(report.failed, 0);
    assert!(report.cache_evictions > 0, "tiny budget must evict");
    assert!(report.cache_bytes <= 2 * 400_000, "budget respected up to the spared newest entry");
    // Per-key counters reconcile with the totals.
    let hits: usize = report.per_key.iter().map(|k| k.hits).sum();
    let misses: usize = report.per_key.iter().map(|k| k.misses).sum();
    let evictions: usize = report.per_key.iter().map(|k| k.evictions).sum();
    assert_eq!(hits, report.cache_hits);
    assert_eq!(misses, report.cache_misses);
    assert_eq!(evictions, report.cache_evictions);
}

#[test]
fn invalid_requests_reject_typed_and_the_rest_complete() {
    let defaults = small_defaults();
    let mut unknown = SolveRequest::new(0, "nosuchmatrix".to_string(), &defaults);
    unknown.max_iters = 10;
    let mut zero_panel = SolveRequest::new(1, "spd".to_string(), &defaults);
    zero_panel.nrhs = 0;
    let mut unbatchable = SolveRequest::new(2, "spd".to_string(), &defaults);
    unbatchable.nrhs = 3;
    unbatchable.solver = SolverKind::Power;
    let requests = vec![
        unknown,
        zero_panel,
        unbatchable,
        SolveRequest::new(3, "spd".to_string(), &defaults),
        SolveRequest::new(4, "spd".to_string(), &defaults),
    ];
    let report = run_service(requests, &ServeConfig::default()).unwrap();
    assert_eq!(report.accounted(), 5);
    assert_eq!(report.completed, 2);
    assert_eq!(report.rejected_invalid, 3);
    assert_eq!(report.failed, 0);
    for o in &report.outcomes {
        if o.id < 3 {
            assert!(
                matches!(o.status, RequestStatus::RejectedInvalid(_)),
                "request {} should be rejected, got {:?}",
                o.id,
                o.status
            );
        }
    }
}

#[test]
fn chaos_session_recovers_every_faulted_request() {
    // Engines dying mid-queue: every third request schedules a rank
    // death inside its own solve. The session must account for every
    // request (zero dropped), recover each faulted one on a rebuilt
    // engine, and keep every served answer — recovered or not — pinned
    // to the one-shot reference at 1e-9.
    let defaults = small_defaults();
    let sources = ["spd", "t2dal", "bcsstm09"];
    let mut requests = Vec::new();
    for id in 0..12 {
        let mut r = SolveRequest::new(id, sources[id % 3].to_string(), &defaults);
        if id % 3 == 0 {
            r.fault_node = Some(1);
            r.fault_apply = Some(1 + id / 3); // kills at applies 1..=4
        }
        requests.push(r);
    }
    let cfg = ServeConfig {
        workers: 3,
        clients: 4,
        keep_solutions: true,
        ..ServeConfig::default()
    };
    let report = run_service(requests.clone(), &cfg).unwrap();

    assert_eq!(report.accounted(), 12, "zero dropped requests");
    assert_eq!(report.failed, 0);
    assert!(report.recovered > 0, "chaos must exercise the recovery path");
    assert_eq!(report.completed + report.recovered, 12);
    assert_eq!(
        report.engines_discarded, report.recovered,
        "each recovery discards exactly one broken engine"
    );

    let mut reference: HashMap<(String, usize), Vec<f64>> = HashMap::new();
    for o in &report.outcomes {
        let spec = requests.iter().find(|r| r.id == o.id).unwrap();
        assert!(o.is_served(), "request {}: {:?}", o.id, o.status);
        if spec.fault_node.is_some() {
            assert_eq!(
                o.status,
                RequestStatus::Recovered,
                "request {} scheduled a death and must recover",
                o.id
            );
            assert!(o.converged, "request {}: recovered solve must converge", o.id);
        }
        let x_ref = reference
            .entry((spec.matrix.clone(), spec.nrhs))
            .or_insert_with(|| one_shot_solution(spec).unwrap().0);
        assert_panel_agrees(&spec.matrix, o.x.as_deref().unwrap(), x_ref);
    }
}

#[test]
fn full_queue_rejections_are_typed_not_dropped() {
    // A 1-deep queue with more clients than workers: whatever is not
    // admitted must surface as a typed RejectedFull outcome, and the
    // books must still balance.
    let defaults = small_defaults();
    let requests: Vec<SolveRequest> =
        (0..12).map(|id| SolveRequest::new(id, "bcsstm09".to_string(), &defaults)).collect();
    let cfg = ServeConfig {
        queue_depth: 1,
        reject_when_full: true,
        workers: 1,
        clients: 6,
        ..ServeConfig::default()
    };
    let report = run_service(requests, &cfg).unwrap();
    assert_eq!(report.accounted(), 12);
    assert_eq!(report.failed, 0);
    assert_eq!(report.completed + report.rejected_full, 12);
    assert!(report.completed >= 1, "at least the admitted head completes");
}

#[test]
fn mtx_ingest_shares_plans_with_the_named_source() {
    // The structural fingerprint sees through the source: the same
    // matrix served from a generator name and from a MatrixMarket file
    // lands on one PlanKey.
    let mtx = write_bcsstm09_mtx("sharing");
    let named = load_matrix("bcsstm09", 1).unwrap();
    let ingested = load_matrix(&mtx, 1).unwrap();
    assert_eq!(fingerprint_csr(&named), fingerprint_csr(&ingested));

    let defaults = small_defaults();
    let requests: Vec<SolveRequest> = ["bcsstm09", mtx.as_str(), "bcsstm09", mtx.as_str()]
        .iter()
        .enumerate()
        .map(|(id, m)| SolveRequest::new(id, m.to_string(), &defaults))
        .collect();
    let cfg = ServeConfig { workers: 2, clients: 2, ..ServeConfig::default() };
    let report = run_service(requests, &cfg).unwrap();
    assert_eq!(report.completed, 4);
    assert_eq!(report.cache_misses, 1, "both sources share one plan");
    assert_eq!(report.cache_hits, 3);
    assert_eq!(report.per_key.len(), 1);
}
