//! Integration: the full distributed PMVC — threaded execution equals the
//! serial product across matrices × combinations × cluster shapes, and the
//! simulator's orderings match the paper's qualitative findings.

use pmvc::cluster::{ClusterTopology, NetworkPreset};
use pmvc::partition::combined::{decompose, Combination, DecomposeConfig};
use pmvc::pmvc::{execute_threads, simulate};
use pmvc::rng::SplitMix64;
use pmvc::sparse::gen::{generate, MatrixSpec};

fn x_for(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = SplitMix64::new(seed);
    (0..n).map(|_| rng.next_f64_range(-1.0, 1.0)).collect()
}

#[test]
fn threaded_execution_equals_serial_across_suite() {
    for name in ["bcsstm09", "thermal", "t2dal"] {
        let a = generate(&MatrixSpec::paper(name).unwrap(), 3).to_csr();
        let x = x_for(a.n_cols, 7);
        let y_ref = a.matvec(&x);
        for combo in Combination::all() {
            for (f, c) in [(2usize, 2usize), (3, 4), (5, 2)] {
                let d = decompose(&a, combo, f, c, &DecomposeConfig::default()).unwrap();
                let r = execute_threads(&d, &x).unwrap();
                for i in 0..a.n_rows {
                    assert!(
                        (r.y[i] - y_ref[i]).abs() < 1e-9 * (1.0 + y_ref[i].abs()),
                        "{name} {combo} f={f} c={c} row {i}"
                    );
                }
            }
        }
    }
}

#[test]
fn simulator_reproduces_paper_orderings_epb1() {
    // Table 4.7 shape: NL-HL should win construction every time and be
    // the best total in the plurality of f values.
    let a = generate(&MatrixSpec::paper("epb1").unwrap(), 1).to_csr();
    let net = NetworkPreset::TenGigabitEthernet.model();
    let mut nl_hl_constr_wins = 0;
    let mut nl_hl_total_wins = 0;
    let fs = [2usize, 4, 8, 16, 32, 64];
    for &f in &fs {
        let topo = ClusterTopology::paravance(f);
        let mut best_constr = (f64::INFINITY, Combination::NlHl);
        let mut best_total = (f64::INFINITY, Combination::NlHl);
        for combo in Combination::all() {
            let d = decompose(&a, combo, f, 8, &DecomposeConfig::default()).unwrap();
            let t = simulate(&d, &topo, &net);
            if t.t_construct < best_constr.0 {
                best_constr = (t.t_construct, combo);
            }
            if t.t_total() < best_total.0 {
                best_total = (t.t_total(), combo);
            }
        }
        nl_hl_constr_wins += usize::from(best_constr.1 == Combination::NlHl);
        nl_hl_total_wins += usize::from(best_total.1 == Combination::NlHl);
    }
    assert_eq!(nl_hl_constr_wins, fs.len(), "NL-HL must win construction 100%");
    assert!(nl_hl_total_wins * 2 >= fs.len(), "NL-HL should win total in most cases");
}

#[test]
fn makespan_scales_down_with_cluster_size() {
    let a = generate(&MatrixSpec::paper("af23560").unwrap(), 1).to_csr();
    let net = NetworkPreset::TenGigabitEthernet.model();
    let mut prev = f64::INFINITY;
    for f in [2usize, 8, 32] {
        let topo = ClusterTopology::paravance(f);
        let d = decompose(&a, Combination::NlHl, f, 8, &DecomposeConfig::default()).unwrap();
        let t = simulate(&d, &topo, &net);
        assert!(t.t_compute < prev, "f={f}");
        prev = t.t_compute;
    }
}

#[test]
fn scatter_grows_with_cluster_size_on_small_matrix() {
    // bcsstm09 rows of the paper: scatter rises from 0.1ms to 8ms as f
    // grows — message count dominates at small payloads
    let a = generate(&MatrixSpec::paper("bcsstm09").unwrap(), 1).to_csr();
    let net = NetworkPreset::TenGigabitEthernet.model();
    let t2 = {
        let d = decompose(&a, Combination::NlHl, 2, 8, &DecomposeConfig::default()).unwrap();
        simulate(&d, &ClusterTopology::paravance(2), &net).t_scatter
    };
    let t64 = {
        let d = decompose(&a, Combination::NlHl, 64, 8, &DecomposeConfig::default()).unwrap();
        simulate(&d, &ClusterTopology::paravance(64), &net).t_scatter
    };
    assert!(t64 > t2, "{t64} !> {t2}");
}

#[test]
fn mpi_backend_agrees_with_threaded_backend() {
    use pmvc::pmvc::MpiCluster;
    let a = generate(&MatrixSpec::paper("thermal").unwrap(), 8).to_csr();
    let x = x_for(a.n_cols, 4);
    for combo in [Combination::NlHl, Combination::NcHc] {
        let d = decompose(&a, combo, 4, 2, &DecomposeConfig::default()).unwrap();
        let rt = execute_threads(&d, &x).unwrap();
        let mut cluster = MpiCluster::launch(&d).unwrap();
        let (ym, times) = cluster.matvec(&x).unwrap();
        for i in 0..a.n_rows {
            assert!((rt.y[i] - ym[i]).abs() < 1e-12, "{combo} row {i}");
        }
        assert!(cluster.t_scatter > 0.0 && times.t_wall > 0.0);
        cluster.shutdown();
    }
}

#[test]
fn rank_killed_between_applies_errors_on_next_apply_without_wedging() {
    use pmvc::pmvc::{make_backend, BackendKind, FaultPlan, OverlapMode};
    use pmvc::solver::{DistributedOp, MatVecOp};
    let a = generate(&MatrixSpec::paper("t2dal").unwrap(), 9).to_csr();
    let x = x_for(a.n_cols, 11);
    let y_ref = a.matvec(&x);
    let topo = ClusterTopology::paravance(3);
    let net = NetworkPreset::TenGigabitEthernet.model();
    for mode in [OverlapMode::Blocking, OverlapMode::Overlapped] {
        let d = decompose(&a, Combination::NlHl, 3, 2, &DecomposeConfig::default()).unwrap();
        let mut backend = make_backend(BackendKind::Mpi, d, &topo, &net).unwrap();
        backend.set_overlap_mode(mode).unwrap();
        // node 1 dies *between* the 2nd and 3rd applies
        backend.set_fault_plan(FaultPlan::new().kill(1, 3)).unwrap();
        let mut op = DistributedOp::with_backend(backend);
        for apply in 0..2 {
            let y = op.apply(&x).unwrap();
            for i in 0..a.n_rows {
                assert!(
                    (y[i] - y_ref[i]).abs() < 1e-9 * (1.0 + y_ref[i].abs()),
                    "{mode} apply {apply} row {i}"
                );
            }
        }
        // the kill fires before the 3rd fan-out: a typed error naming
        // the dead rank, delivered immediately instead of a wedge
        let err = op.apply(&x).unwrap_err();
        assert!(format!("{err:#}").contains("rank 1"), "{mode}: {err:#}");
        // ...and every later apply keeps reporting it deterministically
        for _ in 0..2 {
            let err = op.apply(&x).unwrap_err();
            assert!(format!("{err:#}").contains("rank 1"), "{mode}: {err:#}");
        }
        assert_eq!(op.applications, 2, "failed applies must not count as iterations");
    }
}

#[test]
fn dynamic_scheduling_equals_static_result() {
    use pmvc::pmvc::dynamic::dynamic_spmv;
    let a = generate(&MatrixSpec::paper("t2dal").unwrap(), 2).to_csr();
    let x = x_for(a.n_cols, 3);
    let y_static = a.matvec(&x);
    let r = dynamic_spmv(&a, &x, 4, 32).unwrap();
    for i in 0..a.n_rows {
        assert!((r.y[i] - y_static[i]).abs() < 1e-12, "row {i}");
    }
    assert!(r.t_compute > 0.0);
}

#[test]
fn two_dimensional_pmvc_on_suite_matrix() {
    use pmvc::partition::hypergraph2d::{checkerboard, fine_grain_partition};
    use pmvc::partition::multilevel::Multilevel;
    let a = generate(&MatrixSpec::paper("t2dal").unwrap(), 4).to_csr();
    let x = x_for(a.n_cols, 5);
    let y_ref = a.matvec(&x);
    for owner in [
        checkerboard(&a, 4, 2),
        fine_grain_partition(&a, 8, &Multilevel::default()),
    ] {
        let y = owner.matvec_2d(&a, &x);
        for i in 0..a.n_rows {
            assert!((y[i] - y_ref[i]).abs() < 1e-9 * (1.0 + y_ref[i].abs()), "row {i}");
        }
        // 2D comm volume is finite and bounded by (k-1)(rows+cols)
        let v = owner.comm_volume(&a);
        assert!(v as usize <= (owner.k - 1) * (a.n_rows + a.n_cols));
    }
}

#[test]
fn alternate_formats_agree_with_distributed_pipeline() {
    use pmvc::sparse::formats_ext::{CsrDu, Jad};
    let a = generate(&MatrixSpec::paper("spmsrtls").unwrap(), 2).to_csr();
    let x = x_for(a.n_cols, 6);
    let d = decompose(&a, Combination::NlHl, 2, 4, &DecomposeConfig::default()).unwrap();
    let r = execute_threads(&d, &x).unwrap();
    let mut jad = vec![0.0; a.n_rows];
    Jad::from_csr(&a).mv_into(&x, &mut jad).unwrap();
    let mut du = vec![0.0; a.n_rows];
    CsrDu::from_csr(&a).mv_into(&x, &mut du).unwrap();
    for i in 0..a.n_rows {
        assert!((r.y[i] - jad[i]).abs() < 1e-9, "JAD row {i}");
        assert!((r.y[i] - du[i]).abs() < 1e-9, "CSR-DU row {i}");
    }
}

#[test]
fn phase_times_are_consistent() {
    let a = generate(&MatrixSpec::paper("t2dal").unwrap(), 2).to_csr();
    let x = x_for(a.n_cols, 1);
    let d = decompose(&a, Combination::NlHc, 2, 4, &DecomposeConfig::default()).unwrap();
    let r = execute_threads(&d, &x).unwrap();
    let t = r.times;
    assert!((t.t_total() - (t.t_compute + t.t_gather + t.t_construct)).abs() < 1e-15);
    assert!((t.t_gather_construct() - (t.t_gather + t.t_construct)).abs() < 1e-15);
    assert!(t.lb_nodes >= 1.0 && t.lb_cores >= t.lb_nodes * 0.5);
}
