//! Integration: the plan/engine split — one persistent engine reused
//! across many applies matches the serial product, the plan is built
//! exactly once per decomposition, and all three backends are reachable
//! through the unified [`pmvc::pmvc::ExecBackend`] trait.

use pmvc::cluster::NetworkPreset;
use pmvc::coordinator::experiment::topology_for;
use pmvc::partition::combined::{decompose, Combination, DecomposeConfig};
use pmvc::pmvc::{execute_threads, make_backend, BackendKind, ExecBackend, OverlapMode, PmvcEngine};
use pmvc::rng::SplitMix64;
use pmvc::solver::{Cg, DistributedOp, IterativeSolver, MatVecOp};
use pmvc::sparse::gen::{generate, MatrixSpec};
use std::sync::Arc;

#[test]
fn engine_reuse_matches_serial_for_50_vectors_all_combinations() {
    let a = generate(&MatrixSpec::paper("t2dal").unwrap(), 11).to_csr();
    let mut rng = SplitMix64::new(0xE6);
    for combo in Combination::all() {
        let d = decompose(&a, combo, 2, 4, &DecomposeConfig::default()).unwrap();
        let mut engine = PmvcEngine::new(Arc::new(d)).unwrap();
        // one scratch buffer for all 50 applies — the engine writes in
        // place, nothing is allocated per iteration
        let mut y = vec![0.0; a.n_rows];
        for trial in 0..50 {
            let x: Vec<f64> =
                (0..a.n_cols).map(|_| rng.next_f64_range(-3.0, 3.0)).collect();
            engine.apply_into(&x, &mut y).unwrap();
            let y_ref = a.matvec(&x);
            for i in 0..a.n_rows {
                assert!(
                    (y[i] - y_ref[i]).abs() < 1e-9 * (1.0 + y_ref[i].abs()),
                    "{combo} trial {trial} row {i}: {} vs {}",
                    y[i],
                    y_ref[i]
                );
            }
        }
        assert_eq!(engine.applies(), 50);
        assert_eq!(engine.plan_builds(), 1);
    }
}

#[test]
fn distributed_op_plans_once_for_many_iterations() {
    let a = generate(&MatrixSpec::paper("bcsstm09").unwrap(), 2).to_csr();
    let d = decompose(&a, Combination::NlHl, 2, 2, &DecomposeConfig::default()).unwrap();
    let mut op = DistributedOp::new(d).unwrap();
    let p0 = Arc::as_ptr(op.plan().expect("engine-backed op exposes its plan"));
    let mut rng = SplitMix64::new(3);
    let mut y = vec![0.0; a.n_rows];
    for _ in 0..50 {
        let x: Vec<f64> = (0..a.n_cols).map(|_| rng.next_f64_range(-1.0, 1.0)).collect();
        op.apply_into(&x, &mut y).unwrap();
    }
    assert_eq!(op.applications, 50);
    assert_eq!(op.plan_builds(), 1, "apply must never re-plan");
    assert_eq!(p0, Arc::as_ptr(op.plan().unwrap()), "plan identity stable across applies");
    assert!(op.phase_times().unwrap().t_compute > 0.0);
}

#[test]
fn all_backends_reachable_through_trait_and_agree_with_oneshot() {
    let a = generate(&MatrixSpec::paper("thermal").unwrap(), 5).to_csr();
    let mut rng = SplitMix64::new(14);
    let x: Vec<f64> = (0..a.n_cols).map(|_| rng.next_f64_range(-1.0, 1.0)).collect();
    let (f, c) = (3usize, 2usize);
    let topo = topology_for(f, c);
    let net = NetworkPreset::TenGigabitEthernet.model();
    let d = decompose(&a, Combination::NcHl, f, c, &DecomposeConfig::default()).unwrap();
    let y_oneshot = execute_threads(&d, &x).unwrap().y;
    for kind in BackendKind::all() {
        let mut backend = make_backend(kind, d.clone(), &topo, &net).unwrap();
        assert_eq!(backend.name(), kind.name());
        let r = backend.apply(&x).unwrap();
        for i in 0..a.n_rows {
            assert!(
                (r.y[i] - y_oneshot[i]).abs() < 1e-9 * (1.0 + y_oneshot[i].abs()),
                "{kind} row {i}"
            );
        }
        // a second apply through the allocation-free path reuses state
        let mut y2 = vec![0.0; a.n_rows];
        let t2 = backend.apply_into(&x, &mut y2).unwrap();
        assert_eq!(r.y.len(), y2.len());
        assert!(t2.t_total() > 0.0, "{kind}");
        // the overlapped schedule agrees bitwise on a 3×2 cluster too
        backend.set_overlap_mode(OverlapMode::Overlapped).unwrap();
        let mut y3 = vec![0.0; a.n_rows];
        backend.apply_into(&x, &mut y3).unwrap();
        assert_eq!(y2, y3, "{kind}: overlapped must match blocking bitwise");
    }
}

#[test]
fn solvers_run_over_any_backend() {
    let a = pmvc::sparse::gen::generate_spd(150, 3, 900, 41).to_csr();
    let x_true: Vec<f64> = (0..150).map(|i| ((i % 9) as f64) * 0.5 - 2.0).collect();
    let b = a.matvec(&x_true);
    let (f, c) = (2usize, 2usize);
    let topo = topology_for(f, c);
    let net = NetworkPreset::TenGigabitEthernet.model();
    for kind in BackendKind::all() {
        let d = decompose(&a, Combination::NlHl, f, c, &DecomposeConfig::default()).unwrap();
        let backend = make_backend(kind, d, &topo, &net).unwrap();
        let mut op = DistributedOp::with_backend(backend);
        let r = Cg::new().tol(1e-10).max_iters(600).solve(&mut op, &b).unwrap();
        assert!(r.converged, "{kind}: residual {}", r.residual_norm);
        for i in 0..150 {
            assert!((r.x[i] - x_true[i]).abs() < 1e-6, "{kind} x[{i}]");
        }
        assert_eq!(op.applications, r.iterations);
        assert!(r.phases.is_some(), "{kind}");
    }
}

#[test]
fn corrupt_decomposition_surfaces_error_instead_of_panicking() {
    let a = generate(&MatrixSpec::paper("bcsstm09").unwrap(), 1).to_csr();
    let mut d = decompose(&a, Combination::NlHl, 2, 2, &DecomposeConfig::default()).unwrap();
    let frag = d.fragments.iter_mut().find(|fr| !fr.global_rows.is_empty()).unwrap();
    frag.global_rows.pop();

    assert!(PmvcEngine::new(Arc::new(d.clone())).is_err());
    assert!(execute_threads(&d, &vec![1.0; a.n_cols]).is_err());
    // the operator constructor is eager: no deferred zero-vector hack
    assert!(DistributedOp::new(d).is_err());
}
