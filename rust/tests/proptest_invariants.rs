//! Property-style invariant tests, driven by the deterministic SplitMix64
//! generator (the offline registry carries no proptest; each test sweeps
//! many random cases and shrinks manually by printing the failing seed).

use pmvc::partition::combined::{decompose, Combination, DecomposeConfig};
use pmvc::partition::hypergraph::Hypergraph;
use pmvc::partition::multilevel::Multilevel;
use pmvc::partition::{Axis, Nezgt};
use pmvc::pmvc::{execute_threads, CommPlan, OverlapMode, PmvcEngine};
use pmvc::rng::SplitMix64;
use pmvc::sparse::gen::{generate, Family, MatrixSpec};
use pmvc::sparse::Coo;
use std::sync::Arc;

/// Random sparse matrix for property tests.
fn random_matrix(rng: &mut SplitMix64) -> Coo {
    let n = 20 + rng.next_below(180);
    let density = 0.02 + rng.next_f64() * 0.15;
    let nnz = ((n * n) as f64 * density) as usize + n;
    let spec = MatrixSpec {
        name: "prop",
        n,
        nnz: nnz.min(n * n),
        family: match rng.next_below(3) {
            0 => Family::Band { half_width: 1 + rng.next_below(n / 2) },
            1 => Family::FemStencil { half_width: 1 + rng.next_below(n / 3), long_range: 0.1, symmetric: rng.next_below(2) == 0 },
            _ => Family::Scattered { skew: 1.0 + rng.next_f64() },
        },
        domain: "property test",
    };
    generate(&spec, rng.next_u64())
}

#[test]
fn prop_every_nonzero_owned_exactly_once() {
    let mut rng = SplitMix64::new(0xBEEF);
    for trial in 0..25 {
        let a = random_matrix(&mut rng).to_csr();
        let combo = Combination::all()[rng.next_below(4)];
        let f = 1 + rng.next_below(6);
        let c = 1 + rng.next_below(6);
        let d = decompose(&a, combo, f, c, &DecomposeConfig::default()).unwrap();
        d.validate(&a)
            .unwrap_or_else(|e| panic!("trial {trial} ({combo} f={f} c={c}): {e}"));
    }
}

#[test]
fn prop_distributed_product_equals_serial() {
    let mut rng = SplitMix64::new(0xCAFE);
    for trial in 0..15 {
        let a = random_matrix(&mut rng).to_csr();
        let combo = Combination::all()[rng.next_below(4)];
        let f = 1 + rng.next_below(4);
        let c = 1 + rng.next_below(4);
        let x: Vec<f64> = (0..a.n_cols).map(|_| rng.next_f64_range(-5.0, 5.0)).collect();
        let d = decompose(&a, combo, f, c, &DecomposeConfig::default()).unwrap();
        let r = execute_threads(&d, &x).unwrap();
        let y_ref = a.matvec(&x);
        for i in 0..a.n_rows {
            assert!(
                (r.y[i] - y_ref[i]).abs() < 1e-8 * (1.0 + y_ref[i].abs()),
                "trial {trial} ({combo} f={f} c={c}) row {i}"
            );
        }
    }
}

#[test]
fn prop_nezgt_no_worse_than_unrefined_and_assigns_all() {
    let mut rng = SplitMix64::new(0xF00D);
    for trial in 0..60 {
        let n = 5 + rng.next_below(400);
        let f = 1 + rng.next_below(12);
        let weights: Vec<usize> = (0..n).map(|_| rng.next_below(100)).collect();
        let refined = Nezgt::ligne().partition_weights(&weights, f);
        let raw = Nezgt { refine: false, ..Nezgt::ligne() }.partition_weights(&weights, f);
        refined.validate().unwrap();
        assert_eq!(refined.assign.len(), n);
        assert!(
            refined.fd(&weights) <= raw.fd(&weights),
            "trial {trial}: refinement must not worsen FD"
        );
        // total load preserved
        assert_eq!(
            refined.loads(&weights).iter().sum::<u64>(),
            weights.iter().map(|&w| w as u64).sum::<u64>()
        );
    }
}

#[test]
fn prop_lambda_cut_bounds() {
    let mut rng = SplitMix64::new(0xD1CE);
    for _ in 0..20 {
        let a = random_matrix(&mut rng).to_csr();
        let axis = if rng.next_below(2) == 0 { Axis::Row } else { Axis::Col };
        let hg = Hypergraph::from_matrix(&a, axis);
        let k = 2 + rng.next_below(6);
        let part = Multilevel::default().partition(&hg, k);
        part.validate().unwrap();
        let cut = hg.lambda_minus_one_cut(&part);
        // λ−1 cut is bounded by Σ(min(|net|, k) − 1)
        let bound: u64 = hg
            .nets
            .iter()
            .map(|net| (net.len().min(k) as u64).saturating_sub(1))
            .sum();
        assert!(cut <= bound, "cut {cut} > bound {bound}");
    }
}

#[test]
fn prop_comm_plan_maps_are_permutations_consistent_with_decomposition() {
    let mut rng = SplitMix64::new(0x51AB);
    for trial in 0..20 {
        let a = random_matrix(&mut rng).to_csr();
        let combo = Combination::all()[rng.next_below(4)];
        let f = 1 + rng.next_below(5);
        let c = 1 + rng.next_below(5);
        let d = decompose(&a, combo, f, c, &DecomposeConfig::default()).unwrap();
        let plan = CommPlan::build(&d)
            .unwrap_or_else(|e| panic!("trial {trial} ({combo} f={f} c={c}): {e}"));
        assert_eq!((plan.f, plan.c, plan.n), (f, c, a.n_rows));
        for node in 0..f {
            let np = &plan.nodes[node];
            // footprint lists are duplicate-free, in range, and exactly
            // the union of the node's fragment footprints (a permutation
            // of the distinct ids — same cardinality, no repeats)
            let mut seen_col = vec![false; a.n_cols];
            for &g in &np.x_cols {
                assert!((g as usize) < a.n_cols, "trial {trial} col {g}");
                assert!(!seen_col[g as usize], "trial {trial}: duplicate col {g}");
                seen_col[g as usize] = true;
            }
            assert_eq!(np.x_cols.len(), d.node_x_footprint(node), "trial {trial} node {node}");
            let mut seen_row = vec![false; a.n_rows];
            for &g in &np.y_rows {
                assert!((g as usize) < a.n_rows, "trial {trial} row {g}");
                assert!(!seen_row[g as usize], "trial {trial}: duplicate row {g}");
                seen_row[g as usize] = true;
            }
            assert_eq!(np.y_rows.len(), d.node_y_footprint(node), "trial {trial} node {node}");
            // per-core maps land exactly on the fragment's global ids
            for core in 0..c {
                let frag = d.fragment(node, core);
                assert_eq!(np.core_x_maps[core].len(), frag.global_cols.len());
                for (lc, &p) in np.core_x_maps[core].iter().enumerate() {
                    assert_eq!(np.x_cols[p as usize], frag.global_cols[lc], "trial {trial}");
                }
                assert_eq!(np.core_y_maps[core].len(), frag.global_rows.len());
                for (lr, &p) in np.core_y_maps[core].iter().enumerate() {
                    assert_eq!(np.y_rows[p as usize], frag.global_rows[lr], "trial {trial}");
                }
            }
        }
        // byte accounting covers every fragment of the decomposition
        let expect_a: usize =
            d.fragments.iter().map(|fr| fr.csr.val.len() * 8 + fr.csr.col.len() * 4).sum();
        assert_eq!(plan.scatter_a_bytes(), expect_a, "trial {trial}");
    }
}

#[test]
fn prop_interior_boundary_rows_partition_each_core_exactly() {
    // the overlapped schedule's task split: for every node, interior ∪
    // boundary must cover each core's rows exactly once, and interior
    // rows must never reference a halo column
    let mut rng = SplitMix64::new(0x0B17);
    for trial in 0..20 {
        let a = random_matrix(&mut rng).to_csr();
        let combo = Combination::all()[rng.next_below(4)];
        let f = 1 + rng.next_below(5);
        let c = 1 + rng.next_below(5);
        let d = decompose(&a, combo, f, c, &DecomposeConfig::default()).unwrap();
        let plan = CommPlan::build(&d).unwrap();
        for node in 0..f {
            let np = &plan.nodes[node];
            assert_eq!(
                np.owned_x.len() + np.halo_x.len(),
                np.x_cols.len(),
                "trial {trial} node {node}: owned/halo must split the X footprint"
            );
            let mut owned = vec![false; np.x_cols.len()];
            for &p in &np.owned_x {
                owned[p as usize] = true;
            }
            for core in 0..c {
                let frag = d.fragment(node, core);
                let mut seen = vec![0u8; frag.csr.n_rows];
                for &r in &np.core_interior_rows[core] {
                    seen[r as usize] += 1;
                    // interior rows read owned columns only
                    let (s, e) = (frag.csr.ptr[r as usize], frag.csr.ptr[r as usize + 1]);
                    for &lc in &frag.csr.col[s..e] {
                        let p = np.core_x_maps[core][lc as usize] as usize;
                        assert!(owned[p], "trial {trial}: interior row {r} reads the halo");
                    }
                }
                for &r in &np.core_boundary_rows[core] {
                    seen[r as usize] += 1;
                }
                assert!(
                    seen.iter().all(|&s| s == 1),
                    "trial {trial} ({combo} f={f} c={c}) node {node} core {core}: \
                     rows not partitioned exactly"
                );
            }
        }
    }
}

#[test]
fn prop_overlapped_engine_is_bitwise_equal_to_blocking() {
    let mut rng = SplitMix64::new(0x0E0E);
    for trial in 0..10 {
        let a = random_matrix(&mut rng).to_csr();
        let combo = Combination::all()[rng.next_below(4)];
        let f = 1 + rng.next_below(4);
        let c = 1 + rng.next_below(4);
        let x: Vec<f64> = (0..a.n_cols).map(|_| rng.next_f64_range(-5.0, 5.0)).collect();
        let d = decompose(&a, combo, f, c, &DecomposeConfig::default()).unwrap();
        let mut engine = PmvcEngine::new(Arc::new(d)).unwrap();
        let yb = engine.apply(&x).unwrap().y;
        engine.set_overlap_mode(OverlapMode::Overlapped);
        let yo = engine.apply(&x).unwrap().y;
        assert_eq!(yb, yo, "trial {trial} ({combo} f={f} c={c})");
    }
}

#[test]
fn prop_footprints_cover_matrix_dimensions() {
    let mut rng = SplitMix64::new(0xAB);
    for _ in 0..15 {
        let a = random_matrix(&mut rng).to_csr();
        let combo = Combination::all()[rng.next_below(4)];
        let f = 1 + rng.next_below(5);
        let d = decompose(&a, combo, f, 2, &DecomposeConfig::default()).unwrap();
        // union of node X footprints must cover every column with a nonzero
        let mut covered = vec![false; a.n_cols];
        for node in 0..f {
            for core in 0..2 {
                for &g in &d.fragment(node, core).global_cols {
                    covered[g as usize] = true;
                }
            }
        }
        let col_counts = a.col_counts();
        for j in 0..a.n_cols {
            assert_eq!(covered[j], col_counts[j] > 0, "col {j}");
        }
    }
}

#[test]
fn prop_ell_roundtrip_matches_csr() {
    use pmvc::sparse::ell::Ell;
    let mut rng = SplitMix64::new(0x777);
    for trial in 0..20 {
        let a = random_matrix(&mut rng).to_csr();
        // take a slice that fits the ladder
        let rows: Vec<usize> = (0..a.n_rows.min(64)).collect();
        let frag = a.select_rows(&rows);
        let max_w = (0..frag.n_rows).map(|i| frag.row_nnz(i)).max().unwrap_or(0);
        if max_w > 128 {
            continue;
        }
        let (ell, bucket) = Ell::from_csr_auto(&frag).unwrap();
        assert!(bucket.rows >= frag.n_rows && bucket.width >= max_w);
        let x: Vec<f32> = (0..frag.n_cols).map(|_| rng.next_f64_range(-1.0, 1.0) as f32).collect();
        let mut y_ell = vec![0f32; ell.rows];
        ell.mv_into(&x, &mut y_ell).unwrap();
        let y_csr = frag.matvec(&x.iter().map(|&v| v as f64).collect::<Vec<_>>());
        for i in 0..frag.n_rows {
            let err = (y_ell[i] as f64 - y_csr[i]).abs();
            assert!(err < 1e-3 * (1.0 + y_csr[i].abs()), "trial {trial} row {i}");
        }
    }
}

#[test]
fn prop_formats_roundtrip_csr_and_agree() {
    // CSR ↔ {ELL, DIA, JAD, BSR, CSR-DU} over random structures: the
    // conversion must be lossless (exact CSR equality — the generators
    // never store explicit zeros) and the mv_into kernels must agree
    // with the CSR product at 1e-12
    use pmvc::sparse::formats_ext::{Bsr, CsrDu, Dia, Jad};
    use pmvc::sparse::EllStore;
    let mut rng = SplitMix64::new(0xF0F0);
    for trial in 0..20 {
        let a = random_matrix(&mut rng).to_csr();
        let x: Vec<f64> = (0..a.n_cols).map(|_| rng.next_f64_range(-2.0, 2.0)).collect();
        let y_ref = a.matvec(&x);
        let mut y = vec![0.0; a.n_rows];
        let check = |label: &str, y: &[f64]| {
            for i in 0..a.n_rows {
                assert!(
                    (y[i] - y_ref[i]).abs() < 1e-12 * (1.0 + y_ref[i].abs()),
                    "trial {trial} {label} row {i}"
                );
            }
        };
        let e = EllStore::from_csr(&a);
        assert_eq!(e.to_csr(), a, "trial {trial}: ELL round-trip");
        e.mv_into(&x, &mut y).unwrap();
        check("ell", &y);
        let jad = Jad::from_csr(&a);
        assert_eq!(jad.to_csr(), a, "trial {trial}: JAD round-trip");
        jad.mv_into(&x, &mut y).unwrap();
        check("jad", &y);
        let du = CsrDu::from_csr(&a);
        assert_eq!(du.to_csr(), a, "trial {trial}: CSR-DU round-trip");
        du.mv_into(&x, &mut y).unwrap();
        check("csrdu", &y);
        let b = 1 + rng.next_below(4);
        let bsr = Bsr::from_csr(&a, b);
        assert_eq!(bsr.to_csr(), a, "trial {trial}: BSR b={b} round-trip");
        bsr.mv_into(&x, &mut y).unwrap();
        check("bsr", &y);
        if let Ok(dia) = Dia::from_csr(&a, 4096) {
            assert_eq!(dia.to_csr(), a, "trial {trial}: DIA round-trip");
            dia.mv_into(&x, &mut y).unwrap();
            check("dia", &y);
        }
    }
}

#[test]
fn prop_iterate_remap_round_trips_bitwise_for_arbitrary_partitions() {
    // the recovery path's checkpoint relocation: scattering an iterate
    // into per-node slices of ANY partition layout and gathering it
    // back must be bitwise lossless — pure moves, no arithmetic. Runs
    // over both unconstrained random assignments and the real inter
    // partitions produced by decompose().
    use pmvc::coordinator::{gather_iterate, scatter_iterate};
    use pmvc::partition::Partition;
    let mut rng = SplitMix64::new(0xDEAD);
    for trial in 0..40 {
        let n = 1 + rng.next_below(500);
        let k = 1 + rng.next_below(9);
        let x: Vec<f64> = (0..n).map(|_| rng.next_f64_range(-1e6, 1e6)).collect();
        let assign: Vec<u32> = (0..n).map(|_| rng.next_below(k) as u32).collect();
        let p = Partition { k, assign };
        let slices = scatter_iterate(&p, &x).unwrap();
        assert_eq!(
            slices.iter().map(Vec::len).sum::<usize>(),
            n,
            "trial {trial}: every value lands in exactly one slice"
        );
        let back = gather_iterate(&p, &slices).unwrap();
        assert_eq!(back, x, "trial {trial} (n={n} k={k}): remap must be bitwise");
    }
    // the layouts the recovery driver actually remaps through
    for trial in 0..10 {
        let a = random_matrix(&mut rng).to_csr();
        let combo = Combination::all()[rng.next_below(4)];
        let f = 1 + rng.next_below(5);
        let d = decompose(&a, combo, f, 2, &DecomposeConfig::default()).unwrap();
        let x: Vec<f64> = (0..a.n_rows).map(|_| rng.next_f64_range(-10.0, 10.0)).collect();
        let slices = scatter_iterate(&d.inter, &x).unwrap();
        let back = gather_iterate(&d.inter, &slices).unwrap();
        assert_eq!(back, x, "trial {trial} ({combo} f={f}): decompose layout must round-trip");
    }
}

#[test]
fn prop_2d_matvec_equals_serial() {
    // the ch. 3 §2.4 "version bloc 2D" invariant: any nonzero-level
    // assignment (checkerboard grid or fine-grain hypergraph) must
    // reproduce the serial product exactly
    use pmvc::partition::hypergraph2d::{checkerboard, fine_grain_partition};
    let mut rng = SplitMix64::new(0x2D2D);
    for trial in 0..12 {
        let a = random_matrix(&mut rng).to_csr();
        let x: Vec<f64> = (0..a.n_cols).map(|_| rng.next_f64_range(-3.0, 3.0)).collect();
        let y_ref = a.matvec(&x);
        let p = 1 + rng.next_below(4);
        let q = 1 + rng.next_below(4);
        let mut owners = vec![checkerboard(&a, p, q)];
        if a.nnz() < 3000 {
            // the fine-grain model has one vertex per nonzero — keep the
            // multilevel partitioner's debug-mode cost bounded
            owners.push(fine_grain_partition(&a, p * q, &Multilevel::default()));
        }
        for owner in owners {
            assert_eq!(owner.owner.len(), a.nnz(), "trial {trial} ({p}x{q})");
            assert_eq!(
                owner.loads(a.nnz()).iter().sum::<u64>(),
                a.nnz() as u64,
                "trial {trial}: every nonzero owned exactly once"
            );
            let y = owner.matvec_2d(&a, &x);
            for i in 0..a.n_rows {
                assert!(
                    (y[i] - y_ref[i]).abs() < 1e-9 * (1.0 + y_ref[i].abs()),
                    "trial {trial} ({p}x{q}) row {i}"
                );
            }
        }
    }
}
